"""Graph substrate: segment ops, samplers, generators, batching, data pipeline."""

import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import RapidStore
from repro.core.baselines import CSRGraph
from repro.data.pipeline import GraphUpdateStream, Prefetcher, RecsysBatches, SyntheticTokens
from repro.graph.batching import batch_graphs
from repro.graph.generators import rmat_edges, uniform_edges, update_stream, zipf_edges
from repro.graph.sampler import NeighborSampler, pad_subgraph
from repro.graph.segment_ops import (
    segment_mean,
    segment_softmax,
    segment_std,
    segment_sum,
)


# -- segment ops -----------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 7), st.floats(-10, 10)), min_size=1, max_size=60))
def test_segment_sum_mean_property(pairs):
    ids = np.array([p[0] for p in pairs], np.int32)
    vals = np.array([p[1] for p in pairs], np.float32)
    s = np.asarray(segment_sum(jnp.asarray(vals), jnp.asarray(ids), 8))
    m = np.asarray(segment_mean(jnp.asarray(vals), jnp.asarray(ids), 8))
    for k in range(8):
        sel = vals[ids == k]
        np.testing.assert_allclose(s[k], sel.sum() if len(sel) else 0.0,
                                   rtol=1e-4, atol=1e-4)
        if len(sel):
            np.testing.assert_allclose(m[k], sel.mean(), rtol=1e-4, atol=1e-4)


def test_segment_softmax_normalizes():
    ids = np.array([0, 0, 0, 2, 2], np.int32)
    scores = np.array([1.0, 2.0, 3.0, -1.0, 1.0], np.float32)
    p = np.asarray(segment_softmax(jnp.asarray(scores), jnp.asarray(ids), 3))
    np.testing.assert_allclose(p[:3].sum(), 1.0, rtol=1e-5)
    np.testing.assert_allclose(p[3:].sum(), 1.0, rtol=1e-5)


def test_segment_std_matches_numpy():
    ids = np.array([0, 0, 1, 1, 1], np.int32)
    vals = np.array([[1.0], [3.0], [2.0], [4.0], [6.0]], np.float32)
    s = np.asarray(segment_std(jnp.asarray(vals), jnp.asarray(ids), 2))
    np.testing.assert_allclose(s[0, 0], np.std([1, 3]), rtol=1e-3)
    np.testing.assert_allclose(s[1, 0], np.std([2, 4, 6]), rtol=1e-3)


# -- generators -----------------------------------------------------------------
def test_generators_shapes_and_skew():
    e1 = uniform_edges(100, 500)
    assert e1.shape[1] == 2 and (e1[:, 0] != e1[:, 1]).all()
    e2 = rmat_edges(8, 2000)
    assert e2.max() < 256
    deg = np.bincount(e2[:, 0], minlength=256)
    assert deg.max() > 3 * max(deg.mean(), 1)  # power-law skew
    e3 = zipf_edges(100, 1000)
    assert e3.max() < 100
    ops = update_stream(e1, rounds=2, frac=0.1)
    assert len(ops) == 4 and ops[0][0] == "-" and ops[1][0] == "+"


# -- sampler -----------------------------------------------------------------
def test_neighbor_sampler_fanout_and_validity():
    n = 200
    edges = uniform_edges(n, 3000, seed=1)
    g = CSRGraph.from_edges(n, edges)
    sampler = NeighborSampler(g.neighbors, fanouts=[5, 3], seed=0)
    seeds = np.arange(10, dtype=np.int64)
    sub = sampler.sample(seeds)
    assert sub.n_seeds == 10
    assert np.array_equal(sub.nodes[:10], seeds)
    assert len(sub.blocks) == 2
    edge_set = {(int(u), int(v)) for u, v in zip(edges[:, 0], edges[:, 1])}
    for blk in sub.blocks:
        assert blk.n_edges > 0
        for s, d in zip(blk.src, blk.dst):
            gu, gv = int(sub.nodes[d]), int(sub.nodes[s])
            assert (gu, gv) in edge_set  # message v->u flows along real edge
    # fanout bound: each hop-1 node contributes <= 5 edges
    hop1_per_dst = np.bincount(sub.blocks[0].dst, minlength=sub.n_nodes)
    assert hop1_per_dst[:10].max() <= 5


def test_sampler_over_store_snapshot():
    n = 100
    edges = uniform_edges(n, 1500, seed=2)
    store = RapidStore.from_edges(n, edges, partition_size=16, B=16)
    with store.read_view() as view:
        sampler = NeighborSampler(view.scan, fanouts=[4], seed=1)
        sub = sampler.sample(np.arange(5, dtype=np.int64))
        assert sub.blocks[0].n_edges <= 20
    nodes, src, dst, nm, em = pad_subgraph(sub, 64, 32)
    assert nodes.shape == (64,) and em.sum() == sub.blocks[0].n_edges


def test_pad_subgraph_overflow_raises():
    sub = NeighborSampler(lambda u: np.arange(5, dtype=np.int32), [5], 0).sample(
        np.arange(3, dtype=np.int64))
    with pytest.raises(ValueError):
        pad_subgraph(sub, 2, 100)


# -- batching + pipelines -----------------------------------------------------------
def test_batch_graphs_disjoint():
    b = batch_graphs(4, nodes_per=5, edges_per=6, d_feat=3)
    assert b["node_feat"].shape == (20, 3)
    for g in range(4):
        sl = slice(g * 6, (g + 1) * 6)
        assert (b["src"][sl] >= g * 5).all() and (b["src"][sl] < (g + 1) * 5).all()
    assert list(np.bincount(b["graph_ids"])) == [5] * 4


def test_pipelines_deterministic():
    a = SyntheticTokens(100, 4, 8)[3]
    b = SyntheticTokens(100, 4, 8)[3]
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].max() < 100
    sh = SyntheticTokens(100, 4, 8).shard(3, host=1, n_hosts=2)
    np.testing.assert_array_equal(sh["tokens"], a["tokens"][2:4])
    u = GraphUpdateStream(50, batch=32)[5]
    assert u["insert"].shape[1] == 2
    r = RecsysBatches(1000, 8)[2]
    assert r["hist"].shape == (8, 20) and r["hist"].max() < 1000


def test_prefetcher_orders_batches():
    src = SyntheticTokens(100, 2, 4)
    pf = Prefetcher(src, start=5, depth=2)
    first = next(pf)
    np.testing.assert_array_equal(first["tokens"], src[5]["tokens"])
    second = next(pf)
    np.testing.assert_array_equal(second["tokens"], src[6]["tokens"])
    pf.close()
