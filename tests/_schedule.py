"""Deterministic concurrency-schedule harness for interleaving tests.

Forces a specific thread interleaving through the named hook points the
runtime fires (:data:`repro.core.hooks.RESHARD_HOOKS` — e.g.
``hook_before_flip`` between a migration's staging and its placement flip)
instead of sleeping and hoping.  A test builds a :class:`Schedule`,
*traps* the hook points it wants to park the runtime at, spawns the
concurrent parties, and then scripts the interleaving explicitly:

    with Schedule() as sched:
        sched.trap("hook_before_flip")
        t = sched.spawn(lambda: rb.execute(plan))
        sched.wait("hook_before_flip")   # migration parked pre-flip
        ...open a reader view here...
        sched.release("hook_before_flip")
        sched.join()

``trap`` installs a hook that signals arrival and then blocks until the
test releases it — the trapped thread is parked *inside* the runtime's
critical section, so whatever the test does between ``wait`` and
``release`` is genuinely concurrent with that program point.  ``sync``
gives symmetric barrier-style rendezvous for thread-vs-thread schedules
that don't involve a hook point.

Every blocking primitive carries the schedule's timeout, and any failure
(a spawned thread raising, a barrier breaking, a timeout) aborts the whole
schedule — traps release, barriers break, and ``join`` re-raises — so a
wrong schedule fails the test instead of deadlocking the suite.
"""

import threading

from repro.core.hooks import RESHARD_HOOKS


class ScheduleTimeout(AssertionError):
    """A schedule primitive timed out — the forced interleaving is wrong."""


class Schedule:
    def __init__(self, timeout: float = 60.0, hooks=RESHARD_HOOKS):
        self.timeout = float(timeout)
        self.hooks = hooks
        self._traps = {}      # name -> (reached Event, release Event)
        self._barriers = {}   # name -> threading.Barrier
        self._threads = []
        self._errors = []
        self._lock = threading.Lock()

    # -- hook traps ----------------------------------------------------------
    def trap(self, name: str) -> None:
        """Install a trap: the next thread firing ``name`` parks until
        :meth:`release`.  The trap re-arms on every firing."""
        reached, release = threading.Event(), threading.Event()
        self._traps[name] = (reached, release)

        def _hook(**info):
            reached.set()
            if not release.wait(self.timeout):
                raise ScheduleTimeout(f"trap {name!r} never released")

        self.hooks.set(name, _hook)

    def wait(self, name: str) -> None:
        """Block until a thread is parked at trap ``name``."""
        reached, _ = self._traps[name]
        if not reached.wait(self.timeout):
            self._abort()
            raise ScheduleTimeout(f"trap {name!r} never reached")

    def release(self, name: str) -> None:
        """Unpark the thread at trap ``name`` (and any future arrivals)."""
        self._traps[name][1].set()

    def reached(self, name: str) -> bool:
        return self._traps[name][0].is_set()

    # -- barrier rendezvous ---------------------------------------------------
    def sync(self, name: str, parties: int = 2) -> None:
        """Rendezvous ``parties`` threads at a named point (memoized)."""
        with self._lock:
            bar = self._barriers.get(name)
            if bar is None:
                bar = self._barriers[name] = threading.Barrier(
                    parties, timeout=self.timeout
                )
        try:
            bar.wait()
        except threading.BrokenBarrierError:
            raise ScheduleTimeout(f"barrier {name!r} broken")

    # -- threads --------------------------------------------------------------
    def spawn(self, fn, *args) -> threading.Thread:
        """Run ``fn`` on a schedule-tracked thread; its exception (if any)
        aborts the schedule and re-raises at :meth:`join`."""

        def _run():
            try:
                fn(*args)
            except BaseException as exc:  # noqa: BLE001 - reported via join
                self.fail(exc)

        t = threading.Thread(target=_run, daemon=True)
        self._threads.append(t)
        t.start()
        return t

    def join(self) -> None:
        """Wait for every spawned thread; re-raise the first failure."""
        for t in self._threads:
            t.join(self.timeout)
            if t.is_alive():
                self._abort()
                raise ScheduleTimeout("spawned thread did not finish")
        if self._errors:
            raise self._errors[0]

    def fail(self, exc: BaseException) -> None:
        """Record a failure and abort everything blocked on the schedule."""
        with self._lock:
            self._errors.append(exc)
        self._abort()

    def _abort(self) -> None:
        for bar in self._barriers.values():
            bar.abort()
        for _, release in self._traps.values():
            release.set()

    # -- lifecycle ------------------------------------------------------------
    def close(self) -> None:
        """Uninstall every trap hook and release anything still parked."""
        for name in self._traps:
            self.hooks.set(name, None)
        self._abort()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
