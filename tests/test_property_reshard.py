"""Hypothesis property tests for elastic resharding (core.reshard).

Random interleavings of symmetric writes, deletes, compactions, and tile
migrations against a shard-plane store: every checkpoint view must stay
bitwise-identical to the ``*_uncached`` oracles
(:func:`tests._parity.assert_view_matches_oracles`), every ``*_view`` entry
point must match its independent oracle at the end of the example, and the
edge set must track a plain dict-of-sets oracle — i.e. migration is a pure
placement change, never a data change.

The suite runs on whatever device count the session has: on the
single-device unit-test session every migration folds to a no-op epoch
(the machinery still runs; the placement cannot change), while the
``host-mesh-4-reshard`` tier-1 leg runs it on a forced 4-device mesh where
migrations genuinely move tiles.  With ``REPRO_RESHARD_LIVE=1`` (that CI
leg) a background rebalancer daemon runs *during* every example, so the
random interleavings race a live migration loop.

The deterministic clean-shard identity-reuse contract (shards untouched by
a migration keep their bundles by object identity) runs as a 4-device
subprocess test alongside.
"""

import os

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from _parity import (
    ENTRY_CASES,
    assert_view_matches_oracles,
    hypothesis_examples as _examples,
    make_entry_ctx,
)
from repro.core import RapidStore

N_VERTICES = 64
P = 8  # 8 subgraphs
B = 8

RESHARD_LIVE = os.environ.get("REPRO_RESHARD_LIVE", "") == "1"

edge = st.tuples(
    st.integers(0, N_VERTICES - 1), st.integers(0, N_VERTICES - 1)
).filter(lambda e: e[0] != e[1])

step = st.one_of(
    st.tuples(st.just("write"), st.lists(edge, min_size=1, max_size=6),
              st.lists(edge, min_size=0, max_size=4)),
    st.tuples(st.just("migrate"), st.integers(0, 7), st.integers(1, 3)),
    st.tuples(st.just("compact")),
    st.tuples(st.just("read")),
)


def _sym(pairs):
    """Both directions of every pair (the store stays symmetric, so the
    plane's pull-form analytics keep the bitwise contract)."""
    if not pairs:
        return np.empty((0, 2), np.int64)
    a = np.array(pairs, np.int64)
    return np.concatenate([a, a[:, ::-1]])


@settings(max_examples=_examples(20), deadline=None)
@given(steps=st.lists(step, min_size=3, max_size=16))
def test_random_migrate_interleavings_bitmatch_oracles(steps):
    store = RapidStore(N_VERTICES, partition_size=P, B=B, high_threshold=4)
    plane = store.attach_shard_plane(symmetric=True)
    rb = store.attach_rebalancer()
    comp = store.attach_compactor(min_waste_rows=0)
    if RESHARD_LIVE:
        rb.start(interval=0.01)
    oracle = set()
    epochs0 = len(plane.placement_epochs())
    try:
        for s in steps:
            if s[0] == "write":
                _, ins, dels = s
                store.apply(_sym(ins), _sym(dels))
                oracle |= {tuple(map(int, e)) for e in ins}
                oracle |= {(int(v), int(u)) for u, v in ins}
                oracle -= {tuple(map(int, e)) for e in dels}
                oracle -= {(int(v), int(u)) for u, v in dels}
            elif s[0] == "migrate":
                _, sid, delta = s
                cur = int(plane.placement_for(store.n_subgraphs)[sid])
                dst = (cur + delta) % plane.n_shards
                rb.execute(rb.plan_moves({sid: dst}))
            elif s[0] == "compact":
                comp.compact_once()
            else:  # read
                with store.read_view() as view:
                    assert_view_matches_oracles(view)
                    assert view.edge_set() == oracle
        with store.read_view() as view:
            assert_view_matches_oracles(view)
            assert view.edge_set() == oracle
            ctx = make_entry_ctx(view)
            for name, case in ENTRY_CASES.items():
                assert case(view, ctx), f"entry point diverged: {name}"
        # epochs are monotone and every migration that committed is in the
        # durable placement log
        hist = plane.placement_epochs()
        ts_list = [ts for ts, _ in hist]
        assert ts_list == sorted(ts_list) and len(set(ts_list)) == len(ts_list)
        assert len(store._placement_log) == len(hist) - epochs0
        store.check_invariants()
    finally:
        if RESHARD_LIVE:
            rb.stop()
        store.detach_compactor()


@settings(max_examples=_examples(10), deadline=None)
@given(steps=st.lists(step, min_size=2, max_size=10), seed=st.integers(0, 99))
def test_old_views_pinned_across_migrations(steps, seed):
    """A view pinned before a run of migrations/writes must keep resolving
    its own placement and stay bitwise-stable while newer epochs land."""
    rng = np.random.default_rng(seed)
    e = rng.integers(0, N_VERTICES, size=(120, 2), dtype=np.int64)
    e = e[e[:, 0] != e[:, 1]]
    store = RapidStore.from_edges(
        N_VERTICES, e, undirected=True, partition_size=P, B=B, high_threshold=4
    )
    plane = store.attach_shard_plane(symmetric=True)
    rb = store.attach_rebalancer()
    h = store.begin_read()
    pinned_ts = h.view.ts
    frozen = h.view.edge_set()
    placement0 = plane.placement_at(pinned_ts, store.n_subgraphs).copy()
    try:
        for s in steps:
            if s[0] == "write":
                _, ins, dels = s
                store.apply(_sym(ins), _sym(dels))
            elif s[0] == "migrate":
                _, sid, delta = s
                cur = int(plane.placement_for(store.n_subgraphs)[sid])
                rb.execute(
                    rb.plan_moves({sid: (cur + delta) % plane.n_shards})
                )
        assert h.view.edge_set() == frozen
        assert_view_matches_oracles(h.view)
        # the pinned timestamp still resolves the pre-migration placement
        assert np.array_equal(
            plane.placement_at(pinned_ts, store.n_subgraphs)[: len(placement0)],
            placement0,
        )
    finally:
        store.end_read(h)
