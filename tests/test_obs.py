"""Telemetry plane: metric exactness under concurrency, histogram error
bounds vs numpy, span-ring wraparound, the disabled no-op contract, and
Prometheus / Chrome-trace export round-trips."""

import json
import threading

import numpy as np
import pytest

from repro.core import RapidStore
from repro.core import device_cache
from repro.core import view_assembler
from repro.core.write_pipeline import PipelineStats
from repro.obs.export import chrome_trace, prometheus_text, telemetry_report
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.trace import Span, SpanRing, Tracer

EMPTY = np.empty((0, 2), np.int64)


def _hammer(n_threads, n_iter, fn):
    """Run ``fn(thread_idx, iter_idx)`` from ``n_threads`` threads in lockstep."""
    start = threading.Barrier(n_threads)

    def work(t):
        start.wait()
        for i in range(n_iter):
            fn(t, i)

    threads = [threading.Thread(target=work, args=(t,)) for t in range(n_threads)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()


# ---------------------------------------------------------------------------
# counters / gauges / registry
# ---------------------------------------------------------------------------
def test_counter_exact_under_concurrency():
    c = MetricsRegistry().counter("x")
    _hammer(8, 5000, lambda t, i: c.add())
    assert c.value == 8 * 5000


def test_counter_mirror_runs_under_lock():
    """The mirror callback sees every post-increment value exactly once —
    the mechanism StoreStats uses to keep its dict view exact."""
    c = Counter("x")
    view = {}
    c.mirror = lambda v: view.__setitem__("x", v)
    _hammer(8, 2000, lambda t, i: c.add())
    assert c.value == 8 * 2000
    assert view["x"] == 8 * 2000


def test_gauge_set_max_and_callback():
    g = Gauge("g")
    g.set_max(5)
    g.set_max(3)
    assert g.value == 5
    g.set_fn(lambda: 42)
    assert g.value == 42


def test_registry_identity_and_type_conflict():
    reg = MetricsRegistry()
    assert reg.counter("a") is reg.counter("a")
    assert reg.counter("a", shard="0") is not reg.counter("a", shard="1")
    # same (name, labels) re-requested as a different kind is an error
    with pytest.raises(TypeError):
        reg.gauge("a")
    reg.unregister("a")
    assert isinstance(reg.gauge("a"), Gauge)


# ---------------------------------------------------------------------------
# histogram: log2-bucket error bound vs numpy
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_histogram_percentiles_bracket_numpy(seed):
    rng = np.random.default_rng(seed)
    # spread over ~6 decades: microseconds to hundreds of ms
    data = 10.0 ** rng.uniform(-6, -0.5, size=2000)
    h = Histogram("lat")
    for x in data:
        h.observe(float(x))
    assert h.count == len(data)
    assert h.sum == pytest.approx(float(data.sum()))
    assert h.max == pytest.approx(float(data.max()))
    for q in (50, 90, 99):
        lo = float(np.percentile(data, q, method="lower"))
        hi = float(np.percentile(data, q, method="higher"))
        est = h.percentile(q)
        # bucket upper bound: true sample <= estimate < 2 * true sample
        assert lo <= est <= 2 * hi, (q, lo, est, hi)


def test_histogram_single_value_bound():
    for v in (1e-9, 3e-7, 1e-3, 0.75):
        h = Histogram("one")
        h.observe(v)
        est = h.p50()
        assert v <= est <= 2 * v or est == h.percentile(50)
        assert est >= v  # never under-reports
        assert est <= 2 * v + 1e-12


def test_histogram_buckets_cumulative_and_reset():
    h = Histogram("b")
    for v in (1e-6, 1e-6, 1e-3):
        h.observe(v)
    b = h.buckets()
    assert [c for _, c in b] == sorted(c for _, c in b)  # cumulative
    assert b[-1][1] == h.count == 3
    h.reset()
    assert h.count == 0 and h.buckets() == [] and h.percentile(99) == 0.0


# ---------------------------------------------------------------------------
# span ring: wraparound, striping, disabled no-op
# ---------------------------------------------------------------------------
def test_ring_wraparound_under_concurrent_writers():
    ring = SpanRing(capacity=64, n_stripes=4)
    n_threads, n_iter = 8, 500
    _hammer(
        n_threads, n_iter,
        lambda t, i: ring.record(Span("s", "c", start_ns=i, dur_ns=1, tid=t)),
    )
    assert ring.recorded() == n_threads * n_iter
    retained = ring.spans()
    assert len(retained) <= ring.capacity
    assert ring.dropped() == ring.recorded() - len(retained)


def test_tracer_counts_survive_wraparound():
    tr = Tracer(capacity=32)
    tr.enabled = True
    n_threads, n_iter = 4, 300
    def rec(t, i):
        tok = tr.begin()
        tr.end(tok, "commit" if i % 2 else "read")
    _hammer(n_threads, n_iter, rec)
    total = n_threads * n_iter
    assert tr.count("commit") + tr.count("read") == total
    assert tr.count("commit") == total // 2
    assert len(tr.spans()) <= tr.ring.capacity  # ring bounded, counts exact
    tr.clear()
    assert tr.counts() == {} and tr.spans() == []


def test_tracer_disabled_is_noop():
    tr = Tracer(capacity=64)
    tr.enabled = False
    tok = tr.begin()
    assert tok == 0
    tr.end(tok, "x")
    tr.end(12345, "x")  # stale token after disable: also dropped
    tr.instant("marker")
    assert tr.ring.recorded() == 0
    assert tr.counts() == {}


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------
def test_prometheus_text_roundtrip():
    reg = MetricsRegistry()
    reg.counter("pipeline_writes").add(7)
    reg.gauge("wal_backlog_bytes").set(123.0)
    h = reg.histogram("read_latency_seconds")
    for v in (1e-6, 2e-6, 1e-3):
        h.observe(v)
    text = prometheus_text(reg)
    lines = text.strip().splitlines()
    assert "# TYPE rapidstore_pipeline_writes_total counter" in lines
    assert "rapidstore_pipeline_writes_total 7" in lines
    assert "rapidstore_wal_backlog_bytes 123.0" in lines
    assert "rapidstore_read_latency_seconds_count 3" in lines
    bucket_lines = [l for l in lines if "_bucket{" in l]
    assert bucket_lines and bucket_lines[-1].startswith(
        'rapidstore_read_latency_seconds_bucket{le="+Inf"}'
    )
    cums = [int(l.rsplit(" ", 1)[1]) for l in bucket_lines]
    assert cums == sorted(cums) and cums[-1] == 3
    # every sample line is "name{labels} value"
    for l in lines:
        if not l.startswith("#"):
            name, val = l.rsplit(" ", 1)
            assert name.startswith("rapidstore_")
            float(val)


def test_chrome_trace_json_roundtrip(tmp_path):
    tr = Tracer(capacity=128)
    tr.enabled = True
    tok = tr.begin()
    tr.end(tok, "commit", cat="write", ts=17, args={"n_writes": 3})
    tok = tr.begin()
    tr.end(tok, "read", cat="read", ts=17)
    doc = json.loads(json.dumps(chrome_trace(tr)))
    evs = doc["traceEvents"]
    assert len(evs) == 2
    by_name = {e["name"]: e for e in evs}
    commit = by_name["commit"]
    assert commit["ph"] == "X" and commit["cat"] == "write"
    assert commit["args"]["ts"] == 17 and commit["args"]["n_writes"] == 3
    assert commit["dur"] >= 0 and 0 <= commit["tid"] < (1 << 31)
    assert by_name["read"]["args"]["ts"] == 17
    # file round-trip
    from repro.obs.export import write_chrome_trace

    p = write_chrome_trace(tmp_path / "trace.json", tr)
    assert json.load(open(p))["traceEvents"]


def test_telemetry_report_renders():
    store = RapidStore(64, partition_size=16, B=32)
    store.insert_edge(1, 2)
    with store.read_view() as v:
        v.edge_set()
    off = Tracer(capacity=8)
    off.enabled = False  # a fresh Tracer inherits REPRO_TELEMETRY from env
    rep = telemetry_report(store, tracer=off)
    assert "store_commits" in rep
    assert "reader_horizon_lag" in rep
    assert "store_memory_bytes" in rep
    assert "tracing disabled" in rep
    tr = Tracer(capacity=8)
    tr.enabled = True
    tr.instant("commit")
    rep2 = telemetry_report(store, tracer=tr)
    assert "commit" in rep2 and "ring:" in rep2


# ---------------------------------------------------------------------------
# legacy stat surfaces are registry-backed and exact under threads
# (the PR's racy-counter regression: these used to be unlocked += sites)
# ---------------------------------------------------------------------------
def test_store_stats_dict_view_exact_under_threads():
    store = RapidStore(64, partition_size=16, B=32)
    base = store.stats["commits"]
    _hammer(8, 2000, lambda t, i: store.stats.add("commits"))
    assert store.stats["commits"] == base + 8 * 2000
    assert store.registry.counter("store_commits").value == base + 8 * 2000


def test_assembler_stats_exact_under_threads():
    view_assembler.stats.reset()
    _hammer(8, 2000, lambda t, i: view_assembler._count(
        snapshot_touches=1, spliced_bytes=3))
    assert view_assembler.stats.snapshot_touches == 8 * 2000
    assert view_assembler.stats.spliced_bytes == 8 * 2000 * 3
    view_assembler.stats.reset()
    assert view_assembler.stats.snapshot_touches == 0


def test_device_cache_stats_exact_under_threads():
    before = device_cache.stats.snapshot()
    _hammer(8, 2000, lambda t, i: (device_cache._hit(), device_cache._miss()))
    after = device_cache.stats.snapshot()
    assert after[0] - before[0] == 8 * 2000  # hits
    assert after[1] - before[1] == 8 * 2000  # misses
    ratio = device_cache.stats.hit_ratio()
    assert 0.0 <= ratio <= 1.0


def test_pipeline_stats_exact_under_threads():
    ps = PipelineStats(MetricsRegistry())
    _hammer(8, 2000, lambda t, i: ps.add("writes"))
    assert ps.writes == 8 * 2000
    ps.note_max("max_batch", 7)
    ps.note_max("max_batch", 3)
    assert ps.max_batch == 7


# ---------------------------------------------------------------------------
# reader tracer occupancy gauge + slot exhaustion event
# ---------------------------------------------------------------------------
def test_reader_busy_slots_gauge_and_exhaustion_counter():
    from repro.obs.metrics import REGISTRY

    store = RapidStore(64, partition_size=16, B=32, tracer_k=2)
    store.insert_edge(1, 2)
    gauge = store.registry.gauge("reader_tracer_busy_slots")
    assert gauge.value == 0
    h1 = store.begin_read()
    h2 = store.begin_read()
    assert gauge.value == 2
    exhausted = REGISTRY.counter("reader_slots_exhausted")
    before = exhausted.value
    with pytest.raises(RuntimeError):
        store.begin_read()
    assert exhausted.value == before + 1
    store.end_read(h1)
    store.end_read(h2)
    assert gauge.value == 0
    assert store.stats["reads_begun"] == store.stats["reads_ended"] == 2


# ---------------------------------------------------------------------------
# detach_shard_plane must fully retract its telemetry + device residency
# ---------------------------------------------------------------------------
def test_detach_shard_plane_unregisters_metrics_and_frees_memory():
    """Regression: detaching the shard plane used to leave its per-shard
    gauges/counters registered and pinned shard tiles cached on snapshots —
    an attach/detach cycle leaked registry entries and device bytes.  After
    one warm-up cycle (host caches legitimately persist), a further cycle
    must return both the registry contents and ``memory_bytes()`` exactly
    to their pre-attach values."""
    store = RapidStore(96, partition_size=16, B=8, high_threshold=4)
    rng = np.random.default_rng(3)
    e = rng.integers(0, 96, (200, 2), dtype=np.int64)
    store.insert_edges(e[e[:, 0] != e[:, 1]])

    def assemble():
        plane = store.shard_plane
        with store.read_view() as v:
            plane.sharded_coo(v)
            plane.sharded_blocks(v)

    # warm-up: the first assembly also grows host-side layout caches that
    # survive detach by design; settle into the steady state first
    store.attach_shard_plane()
    assemble()
    store.detach_shard_plane()

    pre_mem = store.memory_bytes()
    pre_metrics = [(m.name, m.labels) for m in store.registry.collect()]
    assert not any(n.startswith("shard_plane_") for n, _ in pre_metrics)

    store.attach_shard_plane()
    assemble()
    mid_names = {m.name for m in store.registry.collect()}
    assert any(n.startswith("shard_plane_") for n in mid_names)
    assert store.memory_bytes() > pre_mem  # pinned tiles are accounted

    store.detach_shard_plane()
    assert [(m.name, m.labels) for m in store.registry.collect()] == pre_metrics
    assert store.memory_bytes() == pre_mem
