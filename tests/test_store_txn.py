"""RapidStore end-to-end: bulk load, transactions, snapshot isolation,
version-chain bound (Prop 5.2), vertex lifecycle, concurrency stress —
including deterministic (barriered) writer/reader interleavings over the
device-resident tile cache."""

import threading

import numpy as np
import pytest

from _parity import pack_padded, rand_edges
from repro.core import RapidStore, device_cache


def oracle_from(edges):
    return {(int(u), int(v)) for u, v in edges}


def test_bulk_load_matches_oracle():
    n, edges = 200, rand_edges(200, 2000)
    store = RapidStore.from_edges(n, edges, partition_size=16, B=32)
    store.check_invariants()
    with store.read_view() as view:
        assert view.edge_set() == oracle_from(edges)
        assert view.n_edges == len(oracle_from(edges))


def test_insert_delete_transactions():
    n = 128
    store = RapidStore(n, partition_size=16, B=32)
    oracle = set()
    rng = np.random.default_rng(1)
    for i in range(10):
        ins = rand_edges(n, 40, seed=i)
        t = store.insert_edges(ins)
        assert t > 0
        oracle |= oracle_from(ins)
        dels = rng.choice(list(oracle), size=min(10, len(oracle)), replace=False)
        store.delete_edges(np.asarray(dels, np.int64))
        oracle -= oracle_from(dels)
        with store.read_view() as view:
            assert view.edge_set() == oracle
    store.check_invariants()


def test_noop_txn_returns_zero():
    store = RapidStore(64, partition_size=16, B=32)
    store.insert_edge(1, 2)
    assert store.insert_edge(1, 2) == 0  # duplicate
    assert store.delete_edge(5, 6) == 0  # absent


def test_snapshot_isolation_under_writes():
    n = 100
    store = RapidStore(n, partition_size=16, B=32)
    store.insert_edges(rand_edges(n, 300, seed=3))
    h = store.begin_read()
    frozen = h.view.edge_set()
    store.insert_edges(rand_edges(n, 200, seed=4))
    store.delete_edges(np.array(list(frozen))[:50])
    assert h.view.edge_set() == frozen  # pinned snapshot unaffected
    store.end_read(h)


def test_version_chain_bound_prop52():
    """Chain length <= k + 1 with k concurrent readers (Prop 5.2)."""
    k = 4
    store = RapidStore(64, partition_size=8, B=16, tracer_k=k)
    handles = []
    for i in range(k):
        store.insert_edge(1, 10 + i)  # version per insert
        handles.append(store.begin_read())  # reader pinning it
    for i in range(10):
        store.insert_edge(1, 40 + i)
    assert store.chain_lengths().max() <= k + 1
    for h in handles:
        store.end_read(h)
    store.insert_edge(1, 63)  # triggers GC with no readers
    assert len(store.chains[0]) == 1
    store.check_invariants()


def test_gc_reclaims_pool_rows():
    store = RapidStore(64, partition_size=8, B=16, tracer_k=4)
    for i in range(50):
        store.insert_edge(int(i % 8), int(8 + i % 40))
    live_before = store.pool.n_live_rows()
    for i in range(40):
        store.delete_edge(int(i % 8), int(8 + i % 40))
    assert store.stats["versions_reclaimed"] > 0
    store.check_invariants()


def test_vertex_insert_delete_and_reuse():
    store = RapidStore(32, partition_size=8, B=16)
    store.insert_edges(np.array([[3, 4], [3, 5]]))
    store.delete_vertex(3)
    with store.read_view() as view:
        assert view.degree(3) == 0
    vid = store.insert_vertex()
    assert vid == 3  # recycled id
    vid2 = store.insert_vertex()
    assert vid2 == 32  # grown id space
    assert store.n_vertices == 33
    store.insert_edge(vid2, 1)
    with store.read_view() as view:
        assert list(view.scan(vid2)) == [1]


def test_batch_update_matches_incremental():
    n = 64
    edges = rand_edges(n, 500, seed=7)
    s1 = RapidStore(n, partition_size=16, B=32)
    s1.insert_edges(edges)  # one big txn
    s2 = RapidStore(n, partition_size=16, B=32)
    for e in edges:  # one txn per edge
        s2.insert_edge(int(e[0]), int(e[1]))
    with s1.read_view() as v1, s2.read_view() as v2:
        assert v1.edge_set() == v2.edge_set()


def test_barriered_pinned_reader_never_sees_mixed_ts_or_stale_tiles():
    """Deterministic writer/reader interleaving on the schedule harness.

    Each round: the reader pins a view and materializes its device tiles;
    the writer then commits several transactions (triggering writer-driven
    GC); the reader re-checks that (a) every subgraph still resolves to the
    exact snapshot visible at its pinned timestamp — no mixed-timestamp
    view, (b) its edge set and device tile bytes are unchanged, and (c) the
    pool-row generation stamps are intact — no stale device tile.  After
    the reader unpins, the writer's next commit reclaims the old versions;
    the epilogue checks they dropped their tiles and refuse to rebuild.
    """
    from _schedule import Schedule

    n = 96
    store = RapidStore.from_edges(
        n, rand_edges(n, 700, seed=31), partition_size=16, B=8,
        high_threshold=4, tracer_k=8,
    )
    rounds = 4
    pinned_history = []  # snaps each round's reader held

    def reader(sched):
        for r in range(rounds):
            h = store.begin_read()
            frozen = h.view.edge_set()
            rows0 = np.asarray(h.view.to_leaf_blocks_device().rows).copy()
            stream0 = h.view.to_leaf_stream().data.copy()
            pinned_history.append(h.view.snaps)
            sched.sync(f"pinned-{r}")  # (a) -> writer commits, we stay pinned
            sched.sync(f"churned-{r}")  # (b) <- writer done committing + GC
            assert h.view.ts < store.clock.read_timestamp()
            for sid, snap in enumerate(h.view.snaps):
                assert snap.ts <= h.view.ts, "snapshot from the future"
                assert store.chains[sid].resolve(h.view.ts) is snap, (
                    "mixed-timestamp view: pinned subgraph version "
                    "no longer resolves at the pinned ts"
                )
            assert h.view.edge_set() == frozen
            dev = h.view.to_leaf_blocks_device()
            assert np.array_equal(np.asarray(dev.rows), rows0)
            assert all(device_cache.tiles_fresh(s) for s in h.view.snaps)
            # the pinned compacted stream is byte-stable too, and its
            # host generation stamps survive the churn
            assert np.array_equal(h.view.to_leaf_stream().data, stream0)
            assert all(s.stream_fresh() for s in h.view.snaps)
            store.end_read(h)
            sched.sync(f"unpinned-{r}")  # (c) -> writer may reclaim now

    def writer(sched):
        for r in range(rounds):
            sched.sync(f"pinned-{r}")  # (a) <- reader pinned
            for i in range(5):
                store.insert_edges(rand_edges(n, 30, seed=1000 + 10 * r + i))
                store.delete_edges(rand_edges(n, 20, seed=2000 + 10 * r + i))
            sched.sync(f"churned-{r}")  # (b) -> reader validates under churn
            sched.sync(f"unpinned-{r}")  # (c) <- reader unpinned
            # this commit's GC can now reclaim the versions it pinned
            store.insert_edges(rand_edges(n, 10, seed=3000 + r))

    with Schedule() as sched:
        sched.spawn(reader, sched)
        sched.spawn(writer, sched)
        sched.join()
    assert store.stats["versions_reclaimed"] > 0
    live = {id(s) for c in store.chains for s in c._versions}
    reclaimed = [s for snaps in pinned_history for s in snaps if id(s) not in live]
    assert reclaimed, "GC should have reclaimed formerly pinned versions"
    for s in reclaimed:
        assert s.device_cache_bytes() == 0 and s.cache_bytes() == 0
        with pytest.raises(RuntimeError, match="released"):
            s.to_leaf_blocks_global()
    store.check_invariants()
    with store.read_view() as v:
        dev = v.to_leaf_blocks_device()
        host = v.to_leaf_blocks_uncached()
        assert np.array_equal(np.asarray(dev.rows), host.rows)


@pytest.mark.slow
def test_concurrent_device_tile_readers_stress():
    """Free-running stress: writers churn + GC while readers race device-tile
    materialization; every observed view must bit-match its own host oracle
    and pass the generation-stamp freshness audit."""
    n = 128
    store = RapidStore.from_edges(
        n, rand_edges(n, 900, seed=37), partition_size=16, B=8,
        high_threshold=4, tracer_k=16,
    )
    errors = []
    stop = threading.Event()

    def writer(seed):
        r = np.random.default_rng(seed)
        try:
            for i in range(30):
                edges = r.integers(0, n, size=(8, 2), dtype=np.int64)
                edges = edges[edges[:, 0] != edges[:, 1]]
                if not len(edges):
                    continue
                if r.random() < 0.6:
                    store.insert_edges(edges)
                else:
                    store.delete_edges(edges)
        except Exception as e:  # pragma: no cover
            errors.append(e)
        finally:
            stop.set()

    def reader(seed):
        try:
            while not stop.is_set():
                with store.read_view() as view:
                    dev = view.to_leaf_blocks_device()
                    host = view.to_leaf_blocks_uncached()
                    assert np.array_equal(np.asarray(dev.src), host.src)
                    assert np.array_equal(np.asarray(dev.rows), host.rows)
                    assert np.array_equal(np.asarray(dev.length), host.length)
                    assert all(device_cache.tiles_fresh(s) for s in view.snaps)
        except Exception as e:  # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=writer, args=(i,)) for i in range(2)]
    threads += [threading.Thread(target=reader, args=(100 + i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    store.check_invariants()


@pytest.mark.slow
def test_concurrent_compacted_stream_readers_stress():
    """Mirror of the device-tile stress for the COMPACTED host stream:
    writers churn edges (deletes free LeafPool rows, inserts recycle them)
    while readers assemble spliced compacted block views.  Every observed
    stream must bit-match the padded per-vertex-loop oracle, the derived
    padded twin must match too, and the host generation-stamp freshness
    audit must hold on every resolved snapshot — a recycled pool row can
    never serve a stale spliced span.  A barriered epilogue additionally
    proves the stamp *detector* trips exactly when rows are recycled under
    a released snapshot."""
    n = 128
    store = RapidStore.from_edges(
        n, rand_edges(n, 900, seed=41), partition_size=16, B=8,
        high_threshold=4, tracer_k=16,
    )
    errors = []
    stop = threading.Event()

    def writer(seed):
        r = np.random.default_rng(seed)
        try:
            for i in range(30):
                edges = r.integers(0, n, size=(8, 2), dtype=np.int64)
                edges = edges[edges[:, 0] != edges[:, 1]]
                if not len(edges):
                    continue
                if r.random() < 0.5:
                    store.insert_edges(edges)
                else:
                    store.delete_edges(edges)
        except Exception as e:  # pragma: no cover
            errors.append(e)
        finally:
            stop.set()

    def reader(seed):
        try:
            while not stop.is_set():
                with store.read_view() as view:
                    stream = view.to_leaf_stream()
                    ob = view.to_leaf_blocks_uncached()
                    odata, _, olens, okeys = pack_padded(ob)
                    assert np.array_equal(stream.data, odata)
                    assert np.array_equal(stream.leaf_lens, olens)
                    assert np.array_equal(stream.leaf_keys, okeys)
                    lb = view.to_leaf_blocks()
                    assert np.array_equal(lb.rows, ob.rows)
                    # generation-stamp freshness on every resolved snapshot
                    assert all(s.stream_fresh() for s in view.snaps)
        except Exception as e:  # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=writer, args=(i,)) for i in range(2)]
    threads += [threading.Thread(target=reader, args=(100 + i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    store.check_invariants()

    # epilogue: prove the freshness detector actually trips on recycling.
    # Pin a view, warm its stream stamps, then churn with no readers so GC
    # frees + recycles the old versions' rows: at least one released
    # snapshot's captured generation must have advanced (the stamp would
    # reject its span), while every LIVE snapshot stays provably fresh.
    with store.read_view() as v:
        v.to_leaf_stream()
        old_snaps = v.snaps
        stamps = {
            s.sid: s._host_gen_stamp for s in old_snaps if s._host_gen_stamp
        }
    assert stamps, "stream materialization must stamp CART-backed snapshots"
    frees0 = store.pool.n_frees
    rng = np.random.default_rng(43)
    for i in range(8):
        store.delete_edges(rand_edges(n, 60, seed=500 + i))
        store.insert_edges(rand_edges(n, 60, seed=600 + i))
    assert store.pool.n_frees > frees0, "churn must actually free pool rows"
    advanced = any(
        not np.array_equal(store.pool.generation[ids], gens)
        for ids, gens in stamps.values()
    )
    assert advanced, "expected a captured row generation to advance"
    with store.read_view() as v2:
        assert all(s.stream_fresh() for s in v2.snaps)
        stream = v2.to_leaf_stream()
        assert np.array_equal(
            stream.data, pack_padded(v2.to_leaf_blocks_uncached())[0]
        )


def test_concurrent_writers_readers_linearizable():
    """Replay-verified consistency under 4 writers + 6 readers."""
    n = 128
    store = RapidStore(n, partition_size=16, B=32, tracer_k=16)
    history, observations, errors = [], [], []
    hlock = threading.Lock()

    def writer(seed):
        r = np.random.default_rng(seed)
        try:
            for _ in range(40):
                edges = r.integers(0, n, size=(6, 2), dtype=np.int64)
                edges = edges[edges[:, 0] != edges[:, 1]]
                if not len(edges):
                    continue
                if r.random() < 0.7:
                    t, op = store.insert_edges(edges), "+"
                else:
                    t, op = store.delete_edges(edges), "-"
                if t > 0:
                    with hlock:
                        history.append((t, op, edges.copy()))
        except Exception as e:  # pragma: no cover
            errors.append(e)

    def reader(seed):
        try:
            for _ in range(20):
                with store.read_view() as view:
                    observations.append((view.ts, frozenset(view.edge_set())))
        except Exception as e:  # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=writer, args=(i,)) for i in range(4)]
    threads += [threading.Thread(target=reader, args=(100 + i,)) for i in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    tss = [h[0] for h in history]
    assert len(set(tss)) == len(tss), "commit timestamps must be unique"
    history.sort(key=lambda h: h[0])
    for obs_ts, obs_edges in observations:
        state = set()
        for t, op, edges in history:
            if t > obs_ts:
                break
            for u, v in edges:
                (state.add if op == "+" else state.discard)((int(u), int(v)))
        assert state == set(obs_edges), f"reader at ts={obs_ts} inconsistent"
    store.check_invariants()
