"""RapidStore end-to-end: bulk load, transactions, snapshot isolation,
version-chain bound (Prop 5.2), vertex lifecycle, concurrency stress."""

import threading

import numpy as np
import pytest

from repro.core import RapidStore


def rand_edges(n, m, seed=0):
    rng = np.random.default_rng(seed)
    e = rng.integers(0, n, size=(m, 2), dtype=np.int64)
    return e[e[:, 0] != e[:, 1]]


def oracle_from(edges):
    return {(int(u), int(v)) for u, v in edges}


def test_bulk_load_matches_oracle():
    n, edges = 200, rand_edges(200, 2000)
    store = RapidStore.from_edges(n, edges, partition_size=16, B=32)
    store.check_invariants()
    with store.read_view() as view:
        assert view.edge_set() == oracle_from(edges)
        assert view.n_edges == len(oracle_from(edges))


def test_insert_delete_transactions():
    n = 128
    store = RapidStore(n, partition_size=16, B=32)
    oracle = set()
    rng = np.random.default_rng(1)
    for i in range(10):
        ins = rand_edges(n, 40, seed=i)
        t = store.insert_edges(ins)
        assert t > 0
        oracle |= oracle_from(ins)
        dels = rng.choice(list(oracle), size=min(10, len(oracle)), replace=False)
        store.delete_edges(np.asarray(dels, np.int64))
        oracle -= oracle_from(dels)
        with store.read_view() as view:
            assert view.edge_set() == oracle
    store.check_invariants()


def test_noop_txn_returns_zero():
    store = RapidStore(64, partition_size=16, B=32)
    store.insert_edge(1, 2)
    assert store.insert_edge(1, 2) == 0  # duplicate
    assert store.delete_edge(5, 6) == 0  # absent


def test_snapshot_isolation_under_writes():
    n = 100
    store = RapidStore(n, partition_size=16, B=32)
    store.insert_edges(rand_edges(n, 300, seed=3))
    h = store.begin_read()
    frozen = h.view.edge_set()
    store.insert_edges(rand_edges(n, 200, seed=4))
    store.delete_edges(np.array(list(frozen))[:50])
    assert h.view.edge_set() == frozen  # pinned snapshot unaffected
    store.end_read(h)


def test_version_chain_bound_prop52():
    """Chain length <= k + 1 with k concurrent readers (Prop 5.2)."""
    k = 4
    store = RapidStore(64, partition_size=8, B=16, tracer_k=k)
    handles = []
    for i in range(k):
        store.insert_edge(1, 10 + i)  # version per insert
        handles.append(store.begin_read())  # reader pinning it
    for i in range(10):
        store.insert_edge(1, 40 + i)
    assert store.chain_lengths().max() <= k + 1
    for h in handles:
        store.end_read(h)
    store.insert_edge(1, 63)  # triggers GC with no readers
    assert len(store.chains[0]) == 1
    store.check_invariants()


def test_gc_reclaims_pool_rows():
    store = RapidStore(64, partition_size=8, B=16, tracer_k=4)
    for i in range(50):
        store.insert_edge(int(i % 8), int(8 + i % 40))
    live_before = store.pool.n_live_rows()
    for i in range(40):
        store.delete_edge(int(i % 8), int(8 + i % 40))
    assert store.stats["versions_reclaimed"] > 0
    store.check_invariants()


def test_vertex_insert_delete_and_reuse():
    store = RapidStore(32, partition_size=8, B=16)
    store.insert_edges(np.array([[3, 4], [3, 5]]))
    store.delete_vertex(3)
    with store.read_view() as view:
        assert view.degree(3) == 0
    vid = store.insert_vertex()
    assert vid == 3  # recycled id
    vid2 = store.insert_vertex()
    assert vid2 == 32  # grown id space
    assert store.n_vertices == 33
    store.insert_edge(vid2, 1)
    with store.read_view() as view:
        assert list(view.scan(vid2)) == [1]


def test_batch_update_matches_incremental():
    n = 64
    edges = rand_edges(n, 500, seed=7)
    s1 = RapidStore(n, partition_size=16, B=32)
    s1.insert_edges(edges)  # one big txn
    s2 = RapidStore(n, partition_size=16, B=32)
    for e in edges:  # one txn per edge
        s2.insert_edge(int(e[0]), int(e[1]))
    with s1.read_view() as v1, s2.read_view() as v2:
        assert v1.edge_set() == v2.edge_set()


def test_concurrent_writers_readers_linearizable():
    """Replay-verified consistency under 4 writers + 6 readers."""
    n = 128
    store = RapidStore(n, partition_size=16, B=32, tracer_k=16)
    history, observations, errors = [], [], []
    hlock = threading.Lock()

    def writer(seed):
        r = np.random.default_rng(seed)
        try:
            for _ in range(40):
                edges = r.integers(0, n, size=(6, 2), dtype=np.int64)
                edges = edges[edges[:, 0] != edges[:, 1]]
                if not len(edges):
                    continue
                if r.random() < 0.7:
                    t, op = store.insert_edges(edges), "+"
                else:
                    t, op = store.delete_edges(edges), "-"
                if t > 0:
                    with hlock:
                        history.append((t, op, edges.copy()))
        except Exception as e:  # pragma: no cover
            errors.append(e)

    def reader(seed):
        try:
            for _ in range(20):
                with store.read_view() as view:
                    observations.append((view.ts, frozenset(view.edge_set())))
        except Exception as e:  # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=writer, args=(i,)) for i in range(4)]
    threads += [threading.Thread(target=reader, args=(100 + i,)) for i in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    tss = [h[0] for h in history]
    assert len(set(tss)) == len(tss), "commit timestamps must be unique"
    history.sort(key=lambda h: h[0])
    for obs_ts, obs_edges in observations:
        state = set()
        for t, op, edges in history:
            if t > obs_ts:
                break
            for u, v in edges:
                (state.add if op == "+" else state.discard)((int(u), int(v)))
        assert state == set(obs_edges), f"reader at ts={obs_ts} inconsistent"
    store.check_invariants()
