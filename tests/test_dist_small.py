"""Multi-device integration tests — run in subprocesses so the forced host
device count never leaks into the (single-device) main test session."""

from _subproc import run_sub


def test_distributed_pagerank_matches_single():
    run_sub("""
    import jax, numpy as np
    from repro.core.distributed import make_pagerank, make_bfs, shard_edges
    from repro.core.analytics import pagerank_coo, bfs_coo
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((8,), ("data",))
    n = 64
    rng = np.random.default_rng(0)
    e = rng.integers(0, n, size=(700, 2), dtype=np.int64)
    e = e[e[:, 0] != e[:, 1]]
    src, dst = e[:, 0], e[:, 1].astype(np.int32)
    s_sh, d_sh, valid = shard_edges(src, dst, 8)
    pr_d = np.asarray(make_pagerank(mesh, "data", n)(s_sh, d_sh, valid))
    pr_s = np.asarray(pagerank_coo(src, dst, n))
    np.testing.assert_allclose(pr_d, pr_s, rtol=1e-5, atol=1e-7)
    lv_d = np.asarray(make_bfs(mesh, "data", n)(s_sh, d_sh, valid, np.int32(0)))
    lv_s = np.asarray(bfs_coo(src, dst, n, 0))
    assert np.array_equal(lv_d, lv_s)
    print("distributed analytics OK")
    """)


def test_distributed_sssp_wcc_match_single():
    run_sub("""
    import jax, numpy as np
    from repro.core.distributed import make_sssp, make_wcc, shard_edges
    from repro.core.analytics import sssp_coo, wcc_coo
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((8,), ("data",))
    n = 64
    rng = np.random.default_rng(3)
    e = rng.integers(0, n, size=(500, 2), dtype=np.int64)
    e = e[e[:, 0] != e[:, 1]]
    src, dst = e[:, 0], e[:, 1].astype(np.int32)
    w = (rng.random(len(src)) + 0.1).astype(np.float32)
    s_sh, d_sh, valid = shard_edges(src, dst, 8)
    w_sh = np.zeros(s_sh.shape, np.float32)
    w_sh.reshape(-1)[: len(w)] = w  # same contiguous-chunk layout as shard_edges
    di_d = np.asarray(make_sssp(mesh, "data", n)(s_sh, d_sh, valid, w_sh, np.int32(0)))
    di_s = np.asarray(sssp_coo(src, dst, w, n, 0))
    # min-merges are order-independent: distributed == single-device bitwise
    assert np.array_equal(di_d.view(np.uint32), di_s.view(np.uint32))
    lb_d = np.asarray(make_wcc(mesh, "data", n)(s_sh, d_sh, valid))
    lb_s = np.asarray(wcc_coo(
        np.concatenate([src, dst.astype(np.int64)]),
        np.concatenate([dst, src.astype(np.int32)]), n))
    assert np.array_equal(lb_d, lb_s)
    print("distributed sssp/wcc OK")
    """)


def test_shard_padding_masked():
    """Regression for the shard_edges padding hazard: pad slots are
    self-loops on vertex 0, and an unmasked kernel would count them into
    vertex 0's degree/rank.  The edge count is chosen indivisible by the
    shard count so padding exists, and vertex 0 carries real edges so the
    corruption would be visible."""
    run_sub("""
    import jax, numpy as np
    from repro.core.distributed import make_pagerank, make_bfs, shard_edges
    from repro.core.analytics import pagerank_coo, bfs_coo
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((8,), ("data",))
    n = 32
    # 13 edges over 8 shards -> per=2, 3 pad slots, all self-loops on 0
    src = np.array([0, 0, 0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 10], np.int64)
    dst = np.array([1, 2, 3, 0, 0, 4, 5, 6, 7, 8, 9, 10, 0], np.int32)
    s_sh, d_sh, valid = shard_edges(src, dst, 8)
    assert valid.sum() == len(src) and (~valid).sum() == 3
    # pad slots really are (0, 0) self-loops: the hazard is live
    assert np.all(s_sh[~valid] == 0) and np.all(d_sh[~valid] == 0)
    pr_d = np.asarray(make_pagerank(mesh, "data", n)(s_sh, d_sh, valid))
    pr_s = np.asarray(pagerank_coo(src, dst, n))
    np.testing.assert_allclose(pr_d, pr_s, rtol=1e-6, atol=1e-9)
    # the test has teeth: an all-true mask (= forgetting `valid`) miscounts
    # vertex 0 and visibly shifts the ranks
    pr_bad = np.asarray(make_pagerank(mesh, "data", n)(s_sh, d_sh, np.ones_like(valid)))
    assert np.abs(pr_bad - pr_s).max() > 1e-4
    lv_d = np.asarray(make_bfs(mesh, "data", n)(s_sh, d_sh, valid, np.int32(3)))
    lv_s = np.asarray(bfs_coo(src, dst, n, 3))
    assert np.array_equal(lv_d, lv_s)
    print("padding mask OK")
    """)


def test_sharded_embedding_lookup_matches_take():
    run_sub("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.models.bst import make_sharded_lookup
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((2, 4), ("data", "model"))
    table = np.random.default_rng(0).normal(size=(64, 8)).astype(np.float32)
    ids = np.random.default_rng(1).integers(0, 64, size=(6, 5)).astype(np.int32)
    lookup = make_sharded_lookup(mesh, "model", batch_axes=None)
    with mesh:
        out = np.asarray(jax.jit(lookup)(table, ids))
    np.testing.assert_allclose(out, table[ids], rtol=1e-6)
    print("sharded lookup OK")
    """)


def test_sp_decode_attention_matches_ref():
    run_sub("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.serve.decode import make_sp_attn_fn
    from repro.models.transformer import decode_attention_ref
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((2, 4), ("data", "model"))
    B, S, KV, H, dh = 4, 64, 2, 4, 8
    rng = np.random.default_rng(0)
    q = rng.normal(size=(B, 1, H, dh)).astype(np.float32)
    kc = rng.normal(size=(B, S, KV, dh)).astype(np.float32)
    vc = rng.normal(size=(B, S, KV, dh)).astype(np.float32)
    pos = jnp.int32(37)
    win = jnp.int32(S)
    fn = make_sp_attn_fn(mesh, ("model",), batch_axes="data")
    with mesh:
        out = np.asarray(jax.jit(lambda *a: fn(*a, None))(q, kc, vc, pos, win))
    ref = np.asarray(decode_attention_ref(q, kc, vc, pos, win, None))
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-5)
    # sliding window variant
    fnw = make_sp_attn_fn(mesh, ("data", "model"), batch_axes=None)
    with mesh:
        outw = np.asarray(jax.jit(lambda *a: fnw(*a, 30.0))(q, kc, vc, pos, jnp.int32(9)))
    refw = np.asarray(decode_attention_ref(q, kc, vc, pos, jnp.int32(9), 30.0))
    np.testing.assert_allclose(outw, refw, rtol=2e-4, atol=2e-5)
    print("sp decode attention OK")
    """)


def test_sharded_moe_matches_local():
    run_sub("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import LMConfig, MoEConfig
    from repro.models.moe import init_moe_layer, make_sharded_moe_ffn, _moe_capacity
    cfg = LMConfig(name='m', n_layers=1, d_model=16, n_heads=2, n_kv_heads=2,
                   d_head=8, d_ff=32, vocab=32,
                   moe=MoEConfig(n_experts=4, top_k=2, d_ff=32, impl='capacity'))
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((2, 4), ('data', 'model'))
    key = jax.random.PRNGKey(0)
    lw = {k: v[0] for k, v in init_moe_layer(cfg, key).items()}
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 16))
    moe_fn = make_sharded_moe_ffn(cfg, mesh, 'data', 'model')
    with mesh:
        y_sharded = np.asarray(jax.jit(moe_fn)(lw, x))
    # local reference: per-data-shard dispatch == full dispatch here because
    # dispatch is independent per token group; compare against two half-batches
    y0 = np.asarray(_moe_capacity(cfg, lw, x[:32]))
    y1 = np.asarray(_moe_capacity(cfg, lw, x[32:]))
    np.testing.assert_allclose(y_sharded, np.concatenate([y0, y1]), rtol=3e-4, atol=3e-5)
    print("sharded moe OK")
    """)


def test_elastic_reshard_roundtrip():
    run_sub("""
    import jax, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.checkpoint.elastic import reshard
    tree = {"w": np.arange(32, dtype=np.float32).reshape(8, 4)}
    specs = {"w": P("data", None)}
    from repro.launch.mesh import make_mesh
    mesh8 = make_mesh((8,), ("data",))
    placed = reshard(tree, specs, mesh8)
    np.testing.assert_array_equal(np.asarray(placed["w"]), tree["w"])
    mesh2 = make_mesh((2,), ("data",))
    placed2 = reshard({"w": np.asarray(placed["w"])}, specs, mesh2)
    np.testing.assert_array_equal(np.asarray(placed2["w"]), tree["w"])
    print("elastic reshard OK")
    """)


def test_compressed_psum_grad_reduce():
    run_sub("""
    import jax, jax.numpy as jnp, numpy as np
    from functools import partial
    from jax.sharding import PartitionSpec as P
    from repro.optim.compression import quantize_int8, psum_compressed
    from repro.launch.mesh import make_mesh
    from repro.jax_compat import shard_map
    mesh = make_mesh((4,), ("pod",))
    g = np.random.default_rng(0).normal(size=(4, 64)).astype(np.float32)

    @partial(shard_map, mesh=mesh, in_specs=P("pod", None), out_specs=P("pod", None),
             check_vma=False)
    def reduce_fn(g_local):
        q, s = quantize_int8(g_local[0])
        mean = psum_compressed({"g": q}, {"g": s}, "pod")["g"]
        return mean[None]

    with mesh:
        out = np.asarray(jax.jit(reduce_fn)(g))
    want = g.mean(0)
    scale = np.abs(g).max() / 127
    assert np.max(np.abs(out[0] - want)) < 2 * scale
    print("compressed psum OK")
    """)
