"""Shared parity fixtures: ``*_view`` entry points vs ``*_uncached`` oracles.

One canonical copy of the helpers that used to be duplicated across
``test_device_cache.py``, ``test_view_assembler.py``, ``test_shard_plane.py``
and the property suites:

- :func:`rand_edges` / :func:`make_store` — the standard small random store;
- :func:`bits` — float32 -> uint32 view for *bitwise* comparisons;
- :func:`pack_padded` — padded ``LeafBlockView`` -> compacted-stream tuple,
  the independent oracle for the compacted layout;
- :func:`assert_view_matches_oracles` — every materialization layout (host
  COO/CSR/padded blocks/compacted stream, device COO/CSR/blocks) asserted
  bitwise against the per-vertex-loop ``*_uncached`` oracles;
- :func:`make_entry_ctx` / :data:`ENTRY_CASES` — the cross-layout fixture
  matrix: each ``*_view`` entry point (kernels + analytics) paired with an
  oracle computed from the uncached arrays, over identical operands.

``tests/test_parity_matrix.py`` runs the matrix across the host / device /
sharded routes and both ``REPRO_DISABLE_DELTA_SPLICE`` legs.
"""

import os

import numpy as np


def hypothesis_examples(default: int) -> int:
    """Example budget for the hypothesis suites: the nightly/``--full`` CI
    leg raises it via ``REPRO_HYPOTHESIS_MAX_EXAMPLES`` (see tier1.yml)."""
    budget = int(os.environ.get("REPRO_HYPOTHESIS_MAX_EXAMPLES", "0"))
    return budget if budget > 0 else default


def rand_edges(n, m, seed=0):
    rng = np.random.default_rng(seed)
    e = rng.integers(0, n, size=(m, 2), dtype=np.int64)
    return e[e[:, 0] != e[:, 1]]


def make_store(n=96, m=900, seed=1, p=16, B=16, ht=8, undirected=False,
               leaf_tiers=None):
    from repro.core import RapidStore

    return RapidStore.from_edges(
        n, rand_edges(n, m, seed), undirected=undirected,
        partition_size=p, B=B, high_threshold=ht, leaf_tiers=leaf_tiers,
    )


def bits(a):
    """Reinterpret float32 as uint32 so equality asserts are bitwise."""
    a = np.asarray(a)
    return a.view(np.uint32) if a.dtype == np.float32 else a


def pack_padded(lb):
    """Pack a padded ``LeafBlockView`` into the compacted-stream tuple
    ``(data, leaf_offsets, leaf_lens, leaf_keys)`` — the independent oracle
    for ``to_leaf_stream`` (never touches the stream code path)."""
    lens = lb.length.astype(np.int64)
    B = lb.rows.shape[1]
    mask = np.arange(B)[None, :] < lens[:, None]
    offsets = np.zeros(len(lens) + 1, np.int64)
    np.cumsum(lens, out=offsets[1:])
    return lb.rows[mask], offsets, lb.length, lb.src


def assert_view_matches_oracles(view):
    """Bitwise parity of every materialization layout vs the uncached
    oracles: host COO/CSR/blocks/stream and device COO/CSR/blocks."""
    src, dst = view.to_coo()
    osrc, odst = view.to_coo_uncached()
    assert np.array_equal(src, osrc) and np.array_equal(dst, odst)
    csr = view.to_csr()
    degs = np.bincount(osrc, minlength=view.n_vertices)
    off = np.zeros(view.n_vertices + 1, np.int64)
    np.cumsum(degs, out=off[1:])
    assert np.array_equal(csr.offsets, off)
    assert np.array_equal(csr.indices, odst)
    ob = view.to_leaf_blocks_uncached()
    lb = view.to_leaf_blocks()
    assert np.array_equal(lb.src, ob.src)
    assert np.array_equal(lb.rows, ob.rows)
    assert np.array_equal(lb.length, ob.length)
    # the compacted stream vs the packed padded oracle
    st = view.to_leaf_stream()
    odata, ooffsets, olens, okeys = pack_padded(ob)
    assert np.array_equal(st.data, odata)
    assert np.array_equal(st.leaf_offsets, ooffsets)
    assert np.array_equal(st.leaf_lens, olens)
    assert np.array_equal(st.leaf_keys, okeys)
    db = view.to_leaf_blocks_device()
    assert np.array_equal(np.asarray(db.src), ob.src)
    assert np.array_equal(np.asarray(db.rows), ob.rows)
    assert np.array_equal(np.asarray(db.length), ob.length)
    dsrc, ddst = view.to_coo_device()
    assert np.array_equal(np.asarray(dsrc), osrc)
    assert np.array_equal(np.asarray(ddst), odst)
    dcsr = view.to_csr_device()
    assert np.array_equal(np.asarray(dcsr.offsets), off)
    assert np.array_equal(np.asarray(dcsr.indices), odst)


# ---------------------------------------------------------------------------
# The *_view entry-point matrix
# ---------------------------------------------------------------------------
def make_entry_ctx(view, seed=0):
    """Shared operands + uncached-oracle arrays for the entry-point matrix.

    Everything downstream oracles need is derived from the ``*_uncached``
    materializers here, once, so every case compares the live entry point
    against the same independent inputs.
    """
    rng = np.random.default_rng(seed)
    n = view.n_vertices
    ob = view.to_leaf_blocks_uncached()
    src_o, dst_o = view.to_coo_uncached()
    nb = max(1, len(ob.src))
    present = np.stack([src_o, dst_o.astype(np.int64)], 1)[:40] if len(src_o) \
        else np.empty((0, 2), np.int64)
    qs = np.concatenate([
        present,
        np.stack([present[:, 0], (present[:, 1] + 1) % n], 1),
    ]) if len(present) else np.empty((0, 2), np.int64)
    return dict(
        n=n,
        blocks=ob,
        src_o=src_o,
        dst_o=dst_o,
        x=rng.normal(size=n).astype(np.float32),
        H=rng.normal(size=(n, 12)).astype(np.float32),
        w=(rng.random(len(src_o)) + 0.1).astype(np.float32),
        ia=rng.integers(0, nb, 24),
        ib=rng.integers(0, nb, 24),
        queries=qs,
    )


def _case_edge_search(view, ctx):
    from repro.kernels.leaf_search import edge_search_view

    qs = ctx["queries"]
    if not len(qs):
        return True
    got = edge_search_view(view, qs[:, 0], qs[:, 1])
    want = np.array([view.search(int(u), int(v)) for u, v in qs])
    return np.array_equal(got, want)


def _case_intersect(view, ctx):
    import jax.numpy as jnp

    from repro.kernels.intersect import intersect_tiles_view
    from repro.kernels.intersect.ref import intersect_count_ref

    ob, ia, ib = ctx["blocks"], ctx["ia"], ctx["ib"]
    if not len(ob.src):
        return True
    got = np.asarray(intersect_tiles_view(view, ia, ib))
    want = np.asarray(
        intersect_count_ref(jnp.asarray(ob.rows[ia]), jnp.asarray(ob.rows[ib]))
    )
    return np.array_equal(got, want)


def _case_sum_intersect(view, ctx):
    import jax.numpy as jnp

    from repro.kernels.intersect import sum_intersect_tiles_view
    from repro.kernels.intersect.ref import intersect_count_ref

    ob, ia, ib = ctx["blocks"], ctx["ia"], ctx["ib"]
    if not len(ob.src):
        return True
    got = sum_intersect_tiles_view(view, ia, ib, batch=16)
    want = int(np.asarray(
        intersect_count_ref(jnp.asarray(ob.rows[ia]), jnp.asarray(ob.rows[ib])),
        np.int64,
    ).sum())
    return got == want


def _case_scan_reduce(view, ctx):
    import jax.numpy as jnp

    from repro.kernels.spmm import leaf_scan_reduce, leaf_scan_reduce_view

    got = np.asarray(leaf_scan_reduce_view(view, jnp.asarray(ctx["x"])))
    want = np.asarray(leaf_scan_reduce(ctx["blocks"].rows, ctx["x"]))
    return np.array_equal(bits(got), bits(want))


def _case_leaf_spmm(view, ctx):
    import jax.numpy as jnp

    from repro.kernels.spmm import leaf_spmm, leaf_spmm_view

    got = np.asarray(leaf_spmm_view(view, jnp.asarray(ctx["H"])))
    want = np.asarray(leaf_spmm(ctx["blocks"].rows, ctx["H"]))
    return np.array_equal(bits(got), bits(want))


def _case_spmm(view, ctx):
    import jax
    import jax.numpy as jnp

    from repro.kernels.spmm import leaf_spmm, spmm_view

    got = np.asarray(spmm_view(view, jnp.asarray(ctx["H"])))
    per_tile = leaf_spmm(ctx["blocks"].rows, ctx["H"])
    want = np.asarray(jax.ops.segment_sum(
        per_tile, jnp.asarray(ctx["blocks"].src), num_segments=ctx["n"]
    ))
    return np.array_equal(bits(got), bits(want))


def _case_pagerank(view, ctx):
    from repro.core.analytics import pagerank_coo, pagerank_view

    got = np.asarray(pagerank_view(view))
    want = np.asarray(
        pagerank_coo(ctx["src_o"], ctx["dst_o"], ctx["n"], iters=10, damping=0.85)
    )
    return np.array_equal(bits(got), bits(want))


def _case_bfs(view, ctx):
    from repro.core.analytics import bfs_coo, bfs_view

    got = np.asarray(bfs_view(view, 0))
    want = np.asarray(bfs_coo(ctx["src_o"], ctx["dst_o"], ctx["n"], 0))
    return np.array_equal(got, want)


def _case_sssp(view, ctx):
    import jax.numpy as jnp

    from repro.core.analytics import sssp_coo, sssp_view

    got = np.asarray(sssp_view(view, ctx["w"], 0))
    want = np.asarray(
        sssp_coo(ctx["src_o"], ctx["dst_o"], jnp.asarray(ctx["w"]), ctx["n"], 0)
    )
    return np.array_equal(bits(got), bits(want))


def _case_wcc(view, ctx):
    import jax.numpy as jnp

    from repro.core.analytics import wcc_coo, wcc_view

    src32 = jnp.asarray(ctx["src_o"], jnp.int32)
    dst32 = jnp.asarray(ctx["dst_o"])
    got = np.asarray(wcc_view(view))
    want = np.asarray(wcc_coo(
        jnp.concatenate([src32, dst32]), jnp.concatenate([dst32, src32]), ctx["n"]
    ))
    return np.array_equal(got, want)


def _case_triangle_count(view, ctx):
    from repro.core.analytics import triangle_count_fast, triangle_count_view
    from repro.core.snapshot import CSRView

    degs = np.bincount(ctx["src_o"], minlength=ctx["n"])
    off = np.zeros(ctx["n"] + 1, np.int64)
    np.cumsum(degs, out=off[1:])
    want = triangle_count_fast(CSRView(off, ctx["dst_o"]))
    return triangle_count_view(view) == want


# name -> case(view, ctx) -> bool; the *_view entry-point fixture matrix
ENTRY_CASES = {
    "edge_search_view": _case_edge_search,
    "intersect_tiles_view": _case_intersect,
    "sum_intersect_tiles_view": _case_sum_intersect,
    "leaf_scan_reduce_view": _case_scan_reduce,
    "leaf_spmm_view": _case_leaf_spmm,
    "spmm_view": _case_spmm,
    "pagerank_view": _case_pagerank,
    "bfs_view": _case_bfs,
    "sssp_view": _case_sssp,
    "wcc_view": _case_wcc,
    "triangle_count_view": _case_triangle_count,
}
