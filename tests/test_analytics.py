"""Analytics vs numpy oracles + snapshot-view materialization."""

import numpy as np
import pytest

from repro.core import RapidStore
from repro.core.analytics import (
    bfs_coo,
    pagerank_coo,
    sssp_coo,
    triangle_count,
    triangle_count_fast,
    wcc_coo,
)
from repro.core.baselines import CSRGraph


def rand_graph(n=80, m=600, seed=0):
    rng = np.random.default_rng(seed)
    e = rng.integers(0, n, size=(m, 2), dtype=np.int64)
    e = e[e[:, 0] != e[:, 1]]
    g = CSRGraph.from_edges(n, e)
    deg = np.diff(g.offsets)
    src = np.repeat(np.arange(n, dtype=np.int64), deg)
    return n, src, g.indices.astype(np.int32), g


def test_pagerank_against_dense():
    n, src, dst, _ = rand_graph()
    pr = np.asarray(pagerank_coo(src, dst, n, iters=30))
    # dense power iteration oracle
    A = np.zeros((n, n))
    A[src, dst] = 1.0
    out_deg = A.sum(1)
    P = np.divide(A, out_deg[:, None], where=out_deg[:, None] > 0)
    p = np.full(n, 1 / n)
    for _ in range(30):
        dangling = p[out_deg == 0].sum()
        p = (1 - 0.85) / n + 0.85 * (P.T @ p + dangling / n)
    np.testing.assert_allclose(pr, p, rtol=1e-4, atol=1e-6)


def test_bfs_levels():
    n, src, dst, g = rand_graph(seed=1)
    lv = np.asarray(bfs_coo(src, dst, n, 0))
    # numpy BFS oracle
    want = np.full(n, -1)
    want[0] = 0
    frontier = [0]
    d = 0
    while frontier:
        nxt = set()
        for u in frontier:
            for v in g.neighbors(u):
                if want[v] < 0:
                    want[v] = d + 1
                    nxt.add(int(v))
        frontier = sorted(nxt)
        d += 1
    assert np.array_equal(lv, want)


def test_sssp_bellman_ford():
    n, src, dst, _ = rand_graph(n=40, m=200, seed=2)
    rng = np.random.default_rng(3)
    w = rng.uniform(0.1, 2.0, len(src)).astype(np.float32)
    dist = np.asarray(sssp_coo(src, dst, w, n, 0))
    want = np.full(n, np.inf)
    want[0] = 0
    for _ in range(n):
        for (u, v, ww) in zip(src, dst, w):
            want[v] = min(want[v], want[u] + ww)
    np.testing.assert_allclose(dist, want, rtol=1e-5, atol=1e-6)


def test_wcc_components():
    # two disjoint cliques + isolated vertex
    edges = [(0, 1), (1, 2), (2, 0), (4, 5), (5, 6)]
    sym = edges + [(v, u) for u, v in edges]
    src = np.array([e[0] for e in sym], np.int64)
    dst = np.array([e[1] for e in sym], np.int32)
    labels = np.asarray(wcc_coo(src, dst, 8))
    assert labels[0] == labels[1] == labels[2]
    assert labels[4] == labels[5] == labels[6]
    assert labels[0] != labels[4]
    assert labels[3] not in (labels[0], labels[4])


def test_triangle_count_vs_matrix_power():
    rng = np.random.default_rng(4)
    e = rng.integers(0, 40, size=(250, 2), dtype=np.int64)
    e = e[e[:, 0] != e[:, 1]]
    g = CSRGraph.from_edges(40, e, undirected=True)
    A = np.zeros((40, 40), bool)
    A[e[:, 0], e[:, 1]] = True
    A |= A.T
    want = int(np.trace(np.linalg.matrix_power(A.astype(np.int64), 3)) // 6)
    assert triangle_count(g) == want
    assert triangle_count_fast(g) == want


def test_analytics_over_store_view():
    n = 60
    rng = np.random.default_rng(5)
    e = rng.integers(0, n, size=(400, 2), dtype=np.int64)
    e = e[e[:, 0] != e[:, 1]]
    store = RapidStore.from_edges(n, e, partition_size=16, B=16)
    with store.read_view() as view:
        src, dst = view.to_coo()
        csr = view.to_csr()
    g = CSRGraph.from_edges(n, e)
    assert np.array_equal(csr.indices, g.indices)
    assert np.array_equal(csr.offsets, g.offsets)
    pr_store = np.asarray(pagerank_coo(src, dst, n))
    deg = np.diff(g.offsets)
    src2 = np.repeat(np.arange(n, dtype=np.int64), deg)
    pr_csr = np.asarray(pagerank_coo(src2, g.indices.astype(np.int32), n))
    np.testing.assert_allclose(pr_store, pr_csr, rtol=1e-6)


def test_leaf_block_view_roundtrip():
    n = 60
    rng = np.random.default_rng(6)
    e = rng.integers(0, n, size=(500, 2), dtype=np.int64)
    e = e[e[:, 0] != e[:, 1]]
    store = RapidStore.from_edges(n, e, partition_size=8, B=16, high_threshold=8)
    with store.read_view() as view:
        lb = view.to_leaf_blocks()
        recon = {}
        for s, row, ln in zip(lb.src, lb.rows, lb.length):
            recon.setdefault(int(s), []).extend(row[:ln].tolist())
        for u in range(n):
            assert sorted(recon.get(u, [])) == sorted(view.scan(u).tolist())
