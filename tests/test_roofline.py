"""Roofline: HLO collective parser on synthetic text + model arithmetic."""

import numpy as np

from repro.roofline.hlo import collective_stats, _shape_bytes
from repro.roofline.model import (
    RooflineReport,
    bst_model_flops,
    gnn_model_flops,
    lm_model_flops,
)
from repro.configs import registry

HLO = """
HloModule jit_step
%x1 = f32[16,128]{1,0} all-reduce(f32[16,128]{1,0} %a), replica_groups=[16,16]<=[256], to_apply=%add
%x2 = bf16[4,256]{1,0} all-gather(bf16[4,16]{1,0} %b), replica_groups={{0,1,2,3}}, dimensions={1}
%x3 = f32[8,8]{1,0} reduce-scatter(f32[64,8]{1,0} %c), replica_groups=[32,8]<=[256], dimensions={0}
%x4 = f32[2,2]{1,0} collective-permute(f32[2,2]{1,0} %d), source_target_pairs={{0,1}}
%x5 = (f32[4,4]{0,1}, f32[4,4]{0,1}) all-to-all(f32[4,4]{0,1} %e, f32[4,4]{0,1} %f), replica_groups=[128,2]<=[256]
%done = f32[4]{0} all-reduce-done(f32[4]{0} %x9)
"""


def test_shape_bytes():
    assert _shape_bytes("f32[16,128]{1,0}") == 16 * 128 * 4
    assert _shape_bytes("bf16[4,16]") == 4 * 16 * 2
    assert _shape_bytes("(f32[2,2]{1,0}, bf16[4]{0})") == 16 + 8
    assert _shape_bytes("pred[8]") == 8


def test_collective_stats_ring_model():
    st = collective_stats(HLO, 256)
    c = st["counts"]
    assert c["all-reduce"] == 1
    assert c["all-gather"] == 1
    assert c["reduce-scatter"] == 1
    assert c["collective-permute"] == 1
    assert c["all-to-all"] == 1
    # all-reduce: 2 * 15/16 * 8192B
    ar = 2 * (15 / 16) * 16 * 128 * 4
    assert abs(st["bytes_by_op"]["all-reduce"] - ar) < 1
    # all-gather: (s-1)/s * result bytes, group size 4
    ag = (3 / 4) * 4 * 256 * 2
    assert abs(st["bytes_by_op"]["all-gather"] - ag) < 1
    # collective-permute: operand bytes
    assert st["bytes_by_op"]["collective-permute"] == 16
    assert st["per_device_bytes"] > 0


def test_roofline_report_terms():
    r = RooflineReport(
        arch="x", shape="y", mesh="16x16", n_devices=256,
        hlo_flops_per_dev=197e12,  # exactly 1 second of compute
        hlo_bytes_per_dev=819e9,  # exactly 1 second of HBM
        coll_bytes_per_dev=25e9,  # 0.5 s of ICI
        model_flops_total=197e12 * 256 * 0.5,
    )
    assert abs(r.compute_s - 1.0) < 1e-9
    assert abs(r.memory_s - 1.0) < 1e-9
    assert abs(r.collective_s - 0.5) < 1e-9
    assert r.bound in ("compute", "memory")
    assert abs(r.mfu_bound - 0.5) < 1e-9
    d = r.to_dict()
    assert d["bound"] == r.bound


def test_model_flops_sane():
    cfg = registry.get_config("qwen2.5-14b")
    f = lm_model_flops(cfg, batch=256, seq=4096, train=True)
    # 6 * 14.5B * 1.05M tokens ~ 9.2e16
    assert 6e16 < f < 1.6e17
    g = gnn_model_flops(registry.get_config("gcn-cora"), 2708, 10556, 1433)
    assert g > 0
    b = bst_model_flops(registry.get_config("bst"), 65536)
    assert b > 0
