"""Elastic resharding tests: placement epochs, the migration runtime, and
the forced-interleaving schedules.

In-process tests cover the host-side machinery on whatever device count the
session has (epoch bookkeeping, WAL migrate records, lineage, recovery,
planning edge cases).  The migration contract itself — a reader opening a
view *between* a migration's SEND and its placement flip must resolve the
old placement and stay bitwise-identical to the static-placement oracle —
needs a real multi-shard plane, so those tests run on a forced 4-host-device
mesh in subprocesses (the tests/_subproc.py launcher) and force the
interleavings with the tests/_schedule.py harness, not sleeps.
"""

import sys
from pathlib import Path

import numpy as np
import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)
TESTS = str(Path(__file__).resolve().parent)

from _parity import rand_edges
from repro.core import RapidStore
from repro.core.wal import KIND_MIGRATE, WriteAheadLog
from repro.core.version_chain import CommitLineage


# ---------------------------------------------------------------------------
# WAL migrate records (pure host)
# ---------------------------------------------------------------------------
def test_wal_migrate_roundtrip(tmp_path):
    path = tmp_path / "wal.log"
    w = WriteAheadLog(path, start_ts=0)
    w.append_migrate(3, {5: 1, 0: 2}, n_vertices=96)
    w.append_migrate(7, {2: 3}, n_vertices=96)
    w.close()
    _, recs, clean = WriteAheadLog.replay(path)
    assert clean and [r.kind for r in recs] == [KIND_MIGRATE, KIND_MIGRATE]
    assert recs[0].ts == 3 and recs[0].moves == {0: 2, 5: 1}
    assert recs[1].ts == 7 and recs[1].moves == {2: 3}
    assert recs[0].n_vertices == 96


def test_wal_migrate_survives_reset(tmp_path):
    path = tmp_path / "wal.log"
    w = WriteAheadLog(path, start_ts=0)
    w.append_migrate(2, {1: 1}, n_vertices=32)
    w.append_migrate(5, {0: 3}, n_vertices=32)
    w.reset(3)  # drop records at or below ts 3
    w.close()
    _, recs, clean = WriteAheadLog.replay(path)
    assert clean and len(recs) == 1
    assert recs[0].kind == KIND_MIGRATE and recs[0].moves == {0: 3}


# ---------------------------------------------------------------------------
# Lineage placement epochs (pure host)
# ---------------------------------------------------------------------------
def test_lineage_placement_epochs_window_and_trim():
    lin = CommitLineage()
    lin.record_placement(4, {0: 1})
    lin.record_placement(9, {2: 3, 1: 0})
    assert lin.placement_epochs_between(0, 3) == []
    assert lin.placement_epochs_between(0, 4) == [(4, {0: 1})]
    assert lin.placement_epochs_between(4, 9) == [(9, {1: 0, 2: 3})]
    # symmetric in its arguments, like dirty_between
    assert lin.placement_epochs_between(9, 4) == [(9, {1: 0, 2: 3})]
    assert lin.placement_epochs_between(5, 5) == []
    lin.record(6, [7])
    lin.trim_below(6)
    assert lin.placement_epochs_between(6, 10) == [(9, {1: 0, 2: 3})]
    # window reaching into the trimmed region is unknowable
    assert lin.placement_epochs_between(3, 10) is None


# ---------------------------------------------------------------------------
# Plane epoch bookkeeping (any device count; 1-device plane suffices)
# ---------------------------------------------------------------------------
def _small_store(**kw):
    return RapidStore.from_edges(
        96, rand_edges(96, 500, seed=4), undirected=True,
        partition_size=16, B=16, high_threshold=8, **kw,
    )


def test_plane_epochs_versioned_and_monotone():
    s = _small_store()
    plane = s.attach_shard_plane(symmetric=True)
    S = s.n_subgraphs
    base = plane.placement_at(0, S).copy()
    assert plane.current_epoch == 0
    plane.record_epoch(5, {0: 0})
    assert plane.current_epoch == 5
    # epochs resolve by timestamp: below 5 -> attach placement
    assert np.array_equal(plane.placement_at(4, S), base)
    assert np.array_equal(plane.placement_at(5, S), plane.placement_for(S))
    with pytest.raises(ValueError):
        plane.record_epoch(5, {1: 0})  # non-monotone epoch ts
    # destination folds modulo the mesh size (recovery portability)
    plane.record_epoch(9, {1: plane.n_shards * 3})
    assert plane.placement_at(9, S)[1] == 0
    hist = plane.placement_epochs()
    assert [ts for ts, _ in hist] == [0, 5, 9]


def test_attach_replays_placement_log_and_recover_restores_it(tmp_path):
    s = RapidStore(96, partition_size=16, B=16, high_threshold=8)
    s.attach_wal(tmp_path / "wal.log")
    e = rand_edges(96, 400, seed=6)
    s.insert_edges(np.concatenate([e, e[:, ::-1]]))
    # a migrate record written the way the rebalancer writes it
    t = s.clock.next_commit_timestamp()
    s.wal.append_migrate(t, {0: 1, 3: 2}, s.n_vertices)
    s.wal.sync()
    s.lineage.record_placement(t, {0: 1, 3: 2})
    s._placement_log.append((t, {0: 1, 3: 2}))
    s.clock.publish(t)
    with s.read_view() as v:
        ref = v.edge_set()
    s.detach_wal()

    rec = RapidStore.recover(
        tmp_path, attach=False, n_vertices=96, partition_size=16, B=16,
        high_threshold=8,
    )
    assert rec._placement_log == [(t, {0: 1, 3: 2})]
    assert rec.lineage.placement_epochs_between(0, t) == [(t, {0: 1, 3: 2})]
    with rec.read_view() as v:
        assert v.edge_set() == ref
    # attaching a plane replays the durable log into epoch history
    plane = rec.attach_shard_plane(symmetric=True)
    assert plane.current_epoch == t
    pl = plane.placement_at(t, rec.n_subgraphs)
    K = plane.n_shards
    assert pl[0] == 1 % K and pl[3] == 2 % K
    # and pre-epoch timestamps still resolve the attach-time placement
    assert plane.placement_at(0, rec.n_subgraphs)[0] == 0


def test_rebalancer_planning_edge_cases():
    s = _small_store()
    plane = s.attach_shard_plane(symmetric=True)
    rb = s.attach_rebalancer()
    # no-op moves (sid already on its destination) are dropped
    cur = int(plane.placement_for(s.n_subgraphs)[0])
    plan = rb.plan_moves({0: cur})
    assert plan.n_moves == 0 and plan.instructions == []
    assert rb.execute(plan) is None
    # signals cover every shard with the load gauge the plane registered
    sig = rb.shard_signals()
    assert set(sig) == set(range(plane.n_shards))
    total = sum(sig[k]["load"] for k in sig)
    with s.read_view() as v:
        assert total == v.n_edges
    if plane.n_shards < 2:
        assert rb.propose() is None  # nowhere to move anything
    s.detach_rebalancer()
    assert s.rebalancer is None
    s.detach_shard_plane()


def test_detach_rebalancer_via_detach_shard_plane():
    s = _small_store()
    s.attach_shard_plane(symmetric=True)
    rb = s.attach_rebalancer()
    rb.start(interval=0.05)
    s.detach_shard_plane()  # must stop + detach the rebalancer first
    assert s.rebalancer is None and s.shard_plane is None
    assert rb._thread is None


# ---------------------------------------------------------------------------
# Mesh entry point
# ---------------------------------------------------------------------------
def test_distributed_shard_mesh_flag_off_matches_local():
    from repro.launch import mesh as lmesh

    assert not lmesh.multihost_enabled()
    assert lmesh.init_distributed() is False
    m = lmesh.distributed_shard_mesh()
    assert list(m.devices.flat) == list(lmesh.make_shard_mesh().devices.flat)


def test_distributed_shard_mesh_subprocess_4dev():
    """The multi-process entry point on a forced 4-host-device mesh:
    flag-off is the local mesh; flag-on initializes the jax.distributed
    runtime as a single-process service and yields the same devices."""
    from _subproc import run_sub

    run_sub("""
    import os
    from repro.launch import mesh as lmesh

    m = lmesh.distributed_shard_mesh()
    assert len(list(m.devices.flat)) == 4
    assert lmesh.distributed_shard_mesh(n_devices=2).devices.size == 2

    os.environ["REPRO_MULTIHOST"] = "1"
    assert lmesh.multihost_enabled()
    try:
        m2 = lmesh.distributed_shard_mesh()
    except Exception as exc:  # single-process distributed init can be
        print("multihost init unavailable:", exc)  # unsupported on CPU builds
    else:
        assert len(list(m2.devices.flat)) == 4
        print("multihost single-process OK")
    print("mesh OK")
    """, devices=4)


# ---------------------------------------------------------------------------
# Forced schedules on a 4-device mesh (subprocesses + schedule harness)
# ---------------------------------------------------------------------------
def run_sub(code: str, prelude: str = "") -> str:
    import textwrap

    from _subproc import run_sub as _run

    # dedent the body here: the sys.path/prelude lines are column-0, which
    # would otherwise defeat the launcher's own dedent of the indented body
    return _run(
        "import sys\n" f"sys.path.insert(0, {TESTS!r})\n"
        + prelude + textwrap.dedent(code),
        devices=4,
    )


_SCHED_PRELUDE = """
import numpy as np
from _parity import assert_view_matches_oracles, bits, rand_edges
from _schedule import Schedule
from repro.core import RapidStore
from repro.core.analytics import pagerank_view

n, p = 96, 16
e = rand_edges(n, 900, seed=3)
kw = dict(undirected=True, partition_size=p, B=16, high_threshold=8)
oracle = RapidStore.from_edges(n, e, **kw)   # static placement, never migrated
store = RapidStore.from_edges(n, e, **kw)
oracle.attach_shard_plane(symmetric=True)
plane = store.attach_shard_plane(symmetric=True)
assert plane.n_shards == 4
rb = store.attach_rebalancer()
"""


def test_reader_between_send_and_flip_is_bitwise_static_4dev():
    """THE acceptance schedule: a reader opens its view while the migration
    runtime is parked between SEND and the placement flip.  The view must
    resolve the old placement and return bitwise-identical results to the
    static-placement oracle — for every materialization layout and for the
    collective analytics."""
    run_sub("""
    plan = rb.plan_moves({0: 1, 2: 3})
    assert plan.n_moves == 2
    old = plane.placement_for(store.n_subgraphs).copy()
    with Schedule() as sched:
        sched.trap("hook_after_send")
        sched.trap("hook_before_flip")
        result = []
        sched.spawn(lambda: result.append(rb.execute(plan)))

        # party 1: parked right after the first SEND upload
        sched.wait("hook_after_send")
        h = store.begin_read(); ho = oracle.begin_read()
        assert_view_matches_oracles(h.view)
        assert np.array_equal(
            bits(pagerank_view(h.view)), bits(pagerank_view(ho.view)))
        # mid-migration view resolves the OLD placement
        assert np.array_equal(
            plane.placement_at(h.view.ts, store.n_subgraphs), old)
        store.end_read(h); oracle.end_read(ho)
        sched.release("hook_after_send")

        # party 2: WAL record synced, epoch not yet recorded/published
        sched.wait("hook_before_flip")
        h = store.begin_read(); ho = oracle.begin_read()
        assert_view_matches_oracles(h.view)
        assert np.array_equal(
            bits(pagerank_view(h.view)), bits(pagerank_view(ho.view)))
        assert np.array_equal(
            plane.placement_at(h.view.ts, store.n_subgraphs), old)
        store.end_read(h); oracle.end_read(ho)
        sched.release("hook_before_flip")
        sched.join()

    epoch = result[0]
    assert epoch is not None
    # post-flip: new placement, still bitwise-equal to the static oracle
    new = plane.placement_at(store.clock.read_timestamp(), store.n_subgraphs)
    assert new[0] == 1 and new[2] == 3
    assert not np.array_equal(new, old)
    h = store.begin_read(); ho = oracle.begin_read()
    assert h.view.ts >= epoch
    assert_view_matches_oracles(h.view)
    assert np.array_equal(
        bits(pagerank_view(h.view)), bits(pagerank_view(ho.view)))
    store.end_read(h); oracle.end_read(ho)
    print("send/flip window OK")
    """, prelude=_SCHED_PRELUDE)


def test_commit_lands_mid_migration_4dev():
    """A write commits while the migration is parked post-SEND: the flip
    still lands, the committed edge is visible, and post-flip views stay
    bitwise-equal to an identically-written static-placement oracle."""
    run_sub("""
    batch = np.array([[3, 70], [70, 3]], np.int64)
    plan = rb.plan_moves({1: 2})
    with Schedule() as sched:
        sched.trap("hook_after_send")
        result = []
        sched.spawn(lambda: result.append(rb.execute(plan)))
        sched.wait("hook_after_send")
        ts_w = store.insert_edges(batch)     # commit mid-migration
        oracle.insert_edges(batch)
        sched.release("hook_after_send")
        sched.join()
    epoch = result[0]
    assert epoch is not None and epoch != ts_w
    assert plane.placement_at(
        store.clock.read_timestamp(), store.n_subgraphs)[1] == 2
    h = store.begin_read(); ho = oracle.begin_read()
    assert h.view.search(3, 70)
    assert_view_matches_oracles(h.view)
    assert np.array_equal(
        bits(pagerank_view(h.view)), bits(pagerank_view(ho.view)))
    store.end_read(h); oracle.end_read(ho)
    print("commit mid-migration OK")
    """, prelude=_SCHED_PRELUDE)


def test_compactor_fold_races_flip_4dev():
    """The compactor folds + repacks while the migration is parked
    post-SEND.  The repack retires the staged snapshots, so whatever the
    runtime decides (abort on the staleness audit, or proceed — both are
    contract-legal) views must remain bitwise-correct and the placement map
    must match the epoch outcome."""
    run_sub("""
    # churn so the fold has versions to retire and rows to repack
    for i in range(6):
        b = rand_edges(n, 40, seed=100 + i)
        sym = np.concatenate([b, b[:, ::-1]])
        store.insert_edges(sym); oracle.insert_edges(sym)
    comp = store.attach_compactor(min_waste_rows=0)
    plan = rb.plan_moves({0: 3})
    with Schedule() as sched:
        sched.trap("hook_after_send")
        result = []
        sched.spawn(lambda: result.append(rb.execute(plan)))
        sched.wait("hook_after_send")
        comp.compact_once()                 # fold + repack mid-migration
        sched.release("hook_after_send")
        sched.join()
    epoch = result[0]
    pl = plane.placement_at(store.clock.read_timestamp(), store.n_subgraphs)
    if epoch is None:
        assert int(pl[0]) == 0, "aborted migration must not move placement"
        assert store.stats.get("reshard_aborts", 0) >= 0
    else:
        assert int(pl[0]) == 3
    h = store.begin_read(); ho = oracle.begin_read()
    assert_view_matches_oracles(h.view)
    assert np.array_equal(
        bits(pagerank_view(h.view)), bits(pagerank_view(ho.view)))
    store.end_read(h); oracle.end_read(ho)
    store.detach_compactor()
    print("compactor race OK:", "committed" if epoch else "aborted")
    """, prelude=_SCHED_PRELUDE)


def test_background_rebalancer_converges_on_skew_4dev():
    """End-to-end: a hub-heavy store, the rebalancer driven to convergence —
    the max/mean shard-load imbalance drops below the threshold and views
    stay bitwise-equal to the static oracle throughout."""
    run_sub("""
    import numpy as np
    from _parity import assert_view_matches_oracles, bits, rand_edges
    from repro.core import RapidStore
    from repro.core.analytics import pagerank_view

    # p=8 -> 12 subgraphs on 4 shards; hot vertex blocks land on sids
    # {0, 4, 8}, ALL of which modulo placement pins on shard 0
    n, p = 96, 8
    rng = np.random.default_rng(0)
    hot = np.concatenate([np.arange(0, 8), np.arange(32, 40), np.arange(64, 72)])
    hub = np.stack([rng.choice(hot, 3000), rng.integers(0, n, 3000)], 1)
    hub = hub[hub[:, 0] != hub[:, 1]]
    base = rand_edges(n, 600, seed=3)
    e = np.concatenate([base, hub])
    kw = dict(undirected=True, partition_size=p, B=16, high_threshold=8)
    oracle = RapidStore.from_edges(n, e, **kw)
    store = RapidStore.from_edges(n, e, **kw)
    oracle.attach_shard_plane(symmetric=True)
    plane = store.attach_shard_plane(symmetric=True)
    rb = store.attach_rebalancer()

    def imbalance():
        sig = rb.shard_signals()
        loads = [sig[k]["load"] for k in sorted(sig)]
        return max(loads) / (sum(loads) / len(loads))

    start = imbalance()
    assert start >= rb.imbalance_threshold
    moved = 0
    for _ in range(8):
        if rb.rebalance_once() is None:
            break
        moved += 1
    assert moved >= 1
    assert imbalance() < rb.imbalance_threshold
    assert store.stats["reshard_migrations"] == moved
    assert store.stats["reshard_sids_moved"] >= moved
    h = store.begin_read(); ho = oracle.begin_read()
    assert_view_matches_oracles(h.view)
    assert np.array_equal(
        bits(pagerank_view(h.view)), bits(pagerank_view(ho.view)))
    store.end_read(h); oracle.end_read(ho)

    # the daemon loop also runs clean (already balanced -> no-op ticks)
    rb.start(interval=0.02)
    import time as _t; _t.sleep(0.2)
    rb.stop()
    print("skew convergence OK, migrations:", moved, "start:", round(start, 2))
    """)


def test_clean_shards_reused_by_identity_across_migration_4dev():
    """Counter + identity contract on a real 4-device mesh: a migration
    touching shards {src, dst} leaves the other shards' bundles identical
    by object identity, with the plane's reuse counter advancing and zero
    uploads charged to the untouched shards."""
    run_sub("""
    import numpy as np
    from repro.core import RapidStore

    # sparse enough that the moved subgraph fits the destination shard's
    # existing column capacity — growth would force a device-local repad of
    # the clean shards instead of identity reuse
    n, p = 96, 8
    rng = np.random.default_rng(1)
    e = rng.integers(0, n, size=(300, 2), dtype=np.int64)
    e = e[e[:, 0] != e[:, 1]]
    s = RapidStore.from_edges(
        n, e, undirected=True, partition_size=p, B=16, high_threshold=8
    )
    plane = s.attach_shard_plane(symmetric=True)
    rb = s.attach_rebalancer()
    assert plane.n_shards == 4

    from repro.core.analytics import pagerank_view

    h0 = s.begin_read()
    pagerank_view(h0.view)               # warm the sharded COO bundles
    pred = h0.view.assembly.sharded.coo
    s.end_read(h0)

    # move sid 0 from shard 0 to shard 1: shards 2 and 3 are untouched
    reuses0 = plane.stats.shard_reuses
    uploads0 = list(plane.stats.uploads)
    assert rb.execute(rb.plan_moves({0: 1})) is not None
    h1 = s.begin_read()
    pagerank_view(h1.view)
    succ = h1.view.assembly.sharded.coo
    for k in (2, 3):
        assert succ.shards[k] is pred.shards[k], f"shard {k} rebuilt"
    assert succ.shards[0] is not pred.shards[0]
    assert succ.shards[1] is not pred.shards[1]
    delta = [a - b for a, b in zip(plane.stats.uploads, uploads0)]
    assert delta[2] == 0 and delta[3] == 0, delta
    assert plane.stats.shard_reuses - reuses0 == 2
    assert plane.stats.migration_rebuilds == 1
    s.end_read(h1)
    print("identity reuse OK")
    """)
