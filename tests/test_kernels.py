"""Per-kernel validation: shape/dtype sweeps, interpret=True vs ref.py oracle."""

import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels.leaf_search import leaf_search
from repro.kernels.leaf_search.ref import leaf_search_ref
from repro.kernels.intersect import intersect_count, intersect_count_hybrid
from repro.kernels.intersect.ref import intersect_count_ref
from repro.kernels.spmm import leaf_scan_reduce, leaf_spmm
from repro.kernels.spmm.ref import leaf_scan_reduce_ref, leaf_spmm_ref
from repro.kernels.embedding_bag import embedding_bag
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.flash_decode import flash_decode
from repro.kernels.flash_decode.ops import flash_decode_partial, merge_partials
from repro.kernels.flash_decode.ref import flash_decode_ref

SENT = np.iinfo(np.int32).max
rng = np.random.default_rng(0)


def sorted_rows(Q, B, universe=5000):
    x = np.full((Q, B), SENT, np.int32)
    for i in range(Q):
        n = rng.integers(0, B + 1)
        if n:
            x[i, :n] = np.sort(rng.choice(universe, size=n, replace=False))
    return x


# -- leaf_search -------------------------------------------------------------
@pytest.mark.parametrize("Q,B", [(1, 128), (7, 128), (300, 512), (64, 256)])
def test_leaf_search_sweep(Q, B):
    rows = sorted_rows(Q, B)
    targets = rng.integers(0, 5000, Q).astype(np.int32)
    for i in range(0, Q, 2):  # force hits
        n = int((rows[i] != SENT).sum())
        if n:
            targets[i] = rows[i, rng.integers(0, n)]
    f, p = leaf_search(rows, targets)
    fr, pr = leaf_search_ref(jnp.asarray(rows), jnp.asarray(targets))
    assert np.array_equal(np.asarray(f), np.asarray(fr))
    assert np.array_equal(np.asarray(p), np.asarray(pr))


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 999), min_size=1, max_size=60),
       st.integers(0, 999))
def test_leaf_search_property(vals, target):
    vals_a = np.unique(np.asarray(vals, np.int32))
    row = np.full((1, 128), SENT, np.int32)
    row[0, : len(vals_a)] = vals_a
    f, p = leaf_search(row, np.array([target], np.int32))
    assert bool(np.asarray(f)[0]) == (target in set(vals))


# -- intersect ----------------------------------------------------------------
@pytest.mark.parametrize("Q,B", [(5, 128), (70, 256), (64, 512)])
def test_intersect_sweep(Q, B):
    a, b = sorted_rows(Q, B, 2000), sorted_rows(Q, B, 2000)
    got = np.asarray(intersect_count(a, b))
    ref = np.asarray(intersect_count_ref(jnp.asarray(a), jnp.asarray(b)))
    assert np.array_equal(got, ref)
    goth = np.asarray(intersect_count_hybrid(a, b))
    assert np.array_equal(goth, ref)


# -- spmm ---------------------------------------------------------------------
@pytest.mark.parametrize("N,B,nv,d", [(10, 128, 300, 16), (100, 512, 1000, 70),
                                      (64, 256, 512, 128)])
def test_spmm_sweep(N, B, nv, d):
    rows = np.full((N, B), SENT, np.int32)
    for i in range(N):
        n = rng.integers(0, B)
        rows[i, :n] = rng.integers(0, nv, n)
    x = rng.normal(size=nv).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(leaf_scan_reduce(rows, x)),
        np.asarray(leaf_scan_reduce_ref(jnp.asarray(rows), jnp.asarray(x))),
        rtol=1e-5, atol=1e-5,
    )
    H = rng.normal(size=(nv, d)).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(leaf_spmm(rows, H)),
        np.asarray(leaf_spmm_ref(jnp.asarray(rows), jnp.asarray(H))),
        rtol=1e-4, atol=1e-4,
    )


# -- embedding_bag -------------------------------------------------------------
@pytest.mark.parametrize("V,d,N,K,mode", [
    (100, 16, 12, 5, "sum"), (1000, 32, 33, 20, "mean"), (64, 8, 4, 3, "sum")])
def test_embedding_bag_sweep(V, d, N, K, mode):
    table = rng.normal(size=(V, d)).astype(np.float32)
    ids = rng.integers(0, V, size=(N, K)).astype(np.int32)
    ids[rng.random(size=(N, K)) < 0.3] = -1
    w = rng.normal(size=(N, K)).astype(np.float32)
    got = np.asarray(embedding_bag(table, ids, w, mode=mode))
    ref = np.asarray(embedding_bag_ref(
        jnp.asarray(table), jnp.asarray(ids), jnp.asarray(w), mode=mode))
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)


def test_embedding_bag_unweighted():
    table = rng.normal(size=(50, 8)).astype(np.float32)
    ids = rng.integers(0, 50, size=(6, 4)).astype(np.int32)
    got = np.asarray(embedding_bag(table, ids))
    ref = np.asarray(embedding_bag_ref(jnp.asarray(table), jnp.asarray(ids)))
    np.testing.assert_allclose(got, ref, rtol=1e-5)


# -- flash_decode ---------------------------------------------------------------
@pytest.mark.parametrize("B,S,KV,G,dh,cap", [
    (2, 256, 2, 4, 64, None), (3, 1000, 4, 2, 128, 50.0), (1, 64, 1, 8, 32, None)])
def test_flash_decode_sweep(B, S, KV, G, dh, cap):
    q = rng.normal(size=(B, KV, G, dh)).astype(np.float32)
    k = rng.normal(size=(B, S, KV, dh)).astype(np.float32)
    v = rng.normal(size=(B, S, KV, dh)).astype(np.float32)
    kv_len = rng.integers(1, S + 1, B).astype(np.int32)
    got = np.asarray(flash_decode(q, k, v, kv_len, block_s=128, softcap=cap))
    ref = np.asarray(flash_decode_ref(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(kv_len), softcap=cap))
    np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-5)


def test_flash_decode_sequence_parallel_merge():
    B, S, KV, G, dh = 2, 512, 2, 4, 64
    q = rng.normal(size=(B, KV, G, dh)).astype(np.float32)
    k = rng.normal(size=(B, S, KV, dh)).astype(np.float32)
    v = rng.normal(size=(B, S, KV, dh)).astype(np.float32)
    kv_len = np.array([500, 128], np.int32)  # second seq entirely in shard 0
    half = S // 2
    p1 = flash_decode_partial(q, k[:, :half], v[:, :half],
                              np.minimum(kv_len, half), block_s=128)
    p2 = flash_decode_partial(q, k[:, half:], v[:, half:],
                              np.maximum(kv_len - half, 0), block_s=128)
    got = np.asarray(merge_partials([p1[0], p2[0]], [p1[1], p2[1]], [p1[2], p2[2]]))
    ref = np.asarray(flash_decode_ref(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(kv_len)))
    np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-5)


def test_flash_decode_bf16():
    B, S, KV, G, dh = 2, 256, 2, 2, 64
    q = rng.normal(size=(B, KV, G, dh)).astype(np.float32)
    k = rng.normal(size=(B, S, KV, dh)).astype(np.float32)
    v = rng.normal(size=(B, S, KV, dh)).astype(np.float32)
    kv_len = np.full(B, S, np.int32)
    got = np.asarray(flash_decode(jnp.asarray(q, jnp.bfloat16), jnp.asarray(k, jnp.bfloat16),
                                  jnp.asarray(v, jnp.bfloat16), kv_len, block_s=128))
    ref = np.asarray(flash_decode_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                                      jnp.asarray(kv_len)))
    np.testing.assert_allclose(got, ref, rtol=2e-2, atol=2e-2)
