import os
import sys
from pathlib import Path

# src layout import without install
SRC = Path(__file__).resolve().parents[1] / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))

# NOTE: no xla_force_host_platform_device_count here — unit/smoke tests run
# on the single real device; multi-device tests spawn subprocesses that set
# the flag before importing jax (see tests/test_dist_small.py).
