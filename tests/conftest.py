import os
import sys
from pathlib import Path

import pytest

# src layout import without install
SRC = Path(__file__).resolve().parents[1] / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))

# NOTE: no xla_force_host_platform_device_count here — unit/smoke tests run
# on the single real device; multi-device tests spawn subprocesses that set
# the flag before importing jax (see tests/test_dist_small.py).

_ACCELERATORS = ("tpu", "gpu", "cuda", "rocm")


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running stress/property test; excluded from the fast tier "
        "(scripts/run_tier1.sh runs -m 'not slow' by default, --full opts in)",
    )
    config.addinivalue_line(
        "markers",
        "device: requires a real accelerator backend (TPU/GPU); "
        "auto-skipped when JAX only sees the CPU",
    )


def pytest_collection_modifyitems(config, items):
    if not any(item.get_closest_marker("device") for item in items):
        return
    import jax  # deferred: only pay the import when device tests are collected

    if jax.default_backend() in _ACCELERATORS:
        return
    skip = pytest.mark.skip(
        reason=f"device marker: JAX backend is '{jax.default_backend()}', "
        "no accelerator available"
    )
    for item in items:
        if item.get_closest_marker("device"):
            item.add_marker(skip)
